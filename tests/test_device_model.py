"""Unit + property tests for the paper's core mechanisms (§4):
kernel table, mediary addresses, map semantics, command protocol."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container image lacks hypothesis
    from _hypothesis_shim import given, settings, st

from repro.core import (DevicePool, HostMirror, KernelTable, MapSpec,
                        MediaryStore, TargetExecutor, sec)
from repro.core.device import Command


# ---------------------------------------------------------------------------
# kernel table (paper §4.1)
# ---------------------------------------------------------------------------
def test_kernel_table_stable_indices():
    """Same registration order → same indices + fingerprint on every 'node'."""
    def build():
        t = KernelTable()
        t.register("a", lambda x: x)
        t.register("b", lambda x: x + 1)
        t.register("c", lambda x: x * 2)
        return t

    host, dev = build(), build()
    for name in ("a", "b", "c"):
        assert host.index_of(name) == dev.index_of(name)
    assert host.fingerprint() == dev.fingerprint()
    # order change ⇒ fingerprint mismatch (the desync the paper must avoid)
    t2 = KernelTable()
    t2.register("b", lambda x: x)
    t2.register("a", lambda x: x)
    t2.register("c", lambda x: x)
    assert t2.fingerprint() != host.fingerprint()


def test_kernel_table_duplicate_rejected():
    t = KernelTable()
    t.register("k", lambda x: x)
    with pytest.raises(ValueError):
        t.register("k", lambda x: x)


def test_kernel_table_switch_dispatch():
    """lax.switch dispatch: the device-side command loop as traced control."""
    t = KernelTable()
    t.register("add1", lambda x: x + 1, signature="unary")
    t.register("dbl", lambda x: x * 2, signature="unary")
    t.register("other", lambda x, y: x + y, signature="binary")
    dispatch = t.switch_dispatch("unary")
    x = jnp.arange(4.0)
    np.testing.assert_allclose(
        jax.jit(dispatch)(t.class_index_of("add1"), x), x + 1)
    np.testing.assert_allclose(
        jax.jit(dispatch)(t.class_index_of("dbl"), x), x * 2)


# ---------------------------------------------------------------------------
# mediary addresses (paper §4.2)
# ---------------------------------------------------------------------------
def test_mediary_first_fit_reuse():
    store = MediaryStore()
    h0 = store.alloc((4,), jnp.float32)
    h1 = store.alloc((4,), jnp.float32)
    assert (h0, h1) == (0, 1)
    store.free(h0)
    assert store.alloc((2,), jnp.int32) == 0     # first-fit reuses slot 0
    with pytest.raises(KeyError):
        store.free(7)


def test_mediary_alloc_is_zeroed():
    """OMPi uses calloc(); ALLOC'd buffers must read as zeros."""
    store = MediaryStore()
    h = store.alloc((3, 2), jnp.float32)
    np.testing.assert_array_equal(store.read(h), np.zeros((3, 2)))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.one_of(
    st.tuples(st.just("alloc"), st.integers(1, 8)),
    st.tuples(st.just("free"), st.integers(0, 30))), max_size=40))
def test_mirror_and_store_handles_always_agree(ops):
    """The paper's no-round-trip optimization: host mirror predicts the
    device's next handle for ANY alloc/free interleaving (property)."""
    mirror, store = HostMirror(), MediaryStore()
    live = []
    for op, arg in ops:
        if op == "alloc":
            hm = mirror.reserve((arg,), jnp.float32)
            hd = store.alloc((arg,), jnp.float32)
            assert hm == hd
            live.append(hm)
        elif live:
            h = live.pop(arg % len(live))
            mirror.free(h)
            store.free(h)
    assert sorted(mirror.live_handles()) == sorted(store.live_handles())


def test_host_mirror_holds_no_data():
    m = HostMirror()
    h = m.reserve((1024, 1024), jnp.float32)
    assert m.nbytes(h) == 1024 * 1024 * 4       # metadata only


# ---------------------------------------------------------------------------
# target regions + map semantics (paper §3)
# ---------------------------------------------------------------------------
@pytest.fixture()
def pool_ex():
    table = KernelTable()

    @table.kernel("saxpy")
    def saxpy(a, b, alpha):
        return {"out": alpha * a + b}

    @table.kernel("inc")
    def inc(buf):
        return {"buf": buf + 1}

    @table.kernel("use_global")
    def use_global(g, x):
        return {"out": g + x}

    pool = DevicePool.virtual(3, table=table)
    return pool, TargetExecutor(pool)


def test_map_to_from_with_firstprivate(pool_ex):
    pool, ex = pool_ex
    a, b = jnp.arange(4.0), jnp.ones(4)
    out = ex.target("saxpy", 0, MapSpec(
        to={"a": a, "b": b},
        from_={"out": jax.ShapeDtypeStruct((4,), jnp.float32)},
        firstprivate={"alpha": 3.0}))
    np.testing.assert_allclose(out["out"], 3.0 * a + b)
    # region teardown freed everything on device 0 and its mirror
    assert pool.devices[0].store.live_handles() == []
    assert pool.mirrors[0].live_handles() == []


def test_map_tofrom_roundtrip(pool_ex):
    pool, ex = pool_ex
    out = ex.target("inc", 1, MapSpec(tofrom={"buf": jnp.zeros(3)}))
    np.testing.assert_allclose(out["buf"], np.ones(3))


def test_array_sections_move_only_slices(pool_ex):
    """Paper Listing 2: only the required elements are copied per device."""
    pool, ex = pool_ex
    big = jnp.arange(100.0)
    before = pool.cost.bytes_moved("to")
    out = ex.target("saxpy", 2, MapSpec(
        to={"a": sec(big, 10, 5), "b": sec(big, 20, 5)},
        from_={"out": jax.ShapeDtypeStruct((5,), jnp.float32)},
        firstprivate={"alpha": 1.0}))
    moved = pool.cost.bytes_moved("to") - before
    assert moved == 2 * 5 * 4                    # two 5-element f32 sections
    np.testing.assert_allclose(out["out"], big[10:15] + big[20:25])


def test_declare_target_globals(pool_ex):
    """Globals installed once at the same handle on every device."""
    pool, ex = pool_ex
    g = jnp.full(8, 2.0)
    h = pool.install_global("g", g)
    assert all(pool.mirrors[d].live_handles() == [h] for d in range(len(pool)))
    out = ex.target("use_global", 1, MapSpec(
        to={"x": jnp.ones(8)},
        from_={"out": jax.ShapeDtypeStruct((8,), jnp.float32)},
        use_globals=("g",)))
    np.testing.assert_allclose(out["out"], 3.0)
    # global survives region teardown (device-lifetime, not region-lifetime)
    assert pool.mirrors[1].live_handles() == [h]


def test_nowait_and_taskwait(pool_ex):
    pool, ex = pool_ex
    futs = [ex.target("inc", d, MapSpec(tofrom={"buf": jnp.full(2, float(d))}),
                      nowait=True) for d in range(3)]
    results = ex.taskwait()
    for d, r in enumerate(results):
        np.testing.assert_allclose(r["buf"], d + 1.0)


def test_command_trace_and_stop(pool_ex):
    pool, ex = pool_ex
    ex.target("inc", 0, MapSpec(tofrom={"buf": jnp.zeros(2)}))
    ops = [c.op for c in pool.trace]
    assert ops == ["ALLOC", "XFER_TO", "EXEC", "XFER_FROM", "FREE"]
    pool.stop_all()
    with pytest.raises(RuntimeError):
        pool.devices[0].execute(Command("EXEC", 0, kernel_index=0), pool.table)


def test_kernel_must_return_mapped_outputs(pool_ex):
    pool, ex = pool_ex
    with pytest.raises(KeyError):
        ex.target("inc", 0, MapSpec(
            to={"buf": jnp.zeros(2)},
            from_={"missing": jax.ShapeDtypeStruct((2,), jnp.float32)}))


def test_config_file_multiplier():
    """Paper §4: 'node 2' in the config file starts 2 devices on that node."""
    pool = DevicePool.from_config(["node0 2", "node1", "# comment"])
    assert len(pool) == 3
    assert [d.hostname for d in pool.devices] == ["node0", "node0", "node1"]
