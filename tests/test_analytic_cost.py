"""Validation of the analytic roofline cost model against XLA.

Strategy: XLA's HloCostAnalysis is exact when no loop runs more than once,
so we compare the analytic model against XLA on L=1 configs with dense
attention and single-chunk SSD (every while trips once).  We also pin the
undercount bug itself, so a future XLA fix is noticed.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_smoke_config, smoke_batch
from repro.launch.analytic_cost import forward_flops, step_cost
from repro.models.model import Model


def _xla_flops(fn, *args) -> float:
    comp = jax.jit(fn).lower(*args).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def _fwd_flops_xla(cfg, B=2, S=64):
    model = Model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = jax.eval_shape(lambda: smoke_batch(cfg, batch=B, seq=S))
    batch.pop("labels", None)
    return _xla_flops(lambda p, b: model.forward(p, b), params, batch)


def test_xla_undercounts_scan():
    """Pin the motivating bug: 4× more scanned layers ≠ 4× reported flops.
    If this starts failing, XLA fixed trip-count handling and the analytic
    model can be cross-checked at full depth."""
    base = get_smoke_config("minitron-4b").replace(remat="none",
                                                   attn_impl="dense")
    f2 = _fwd_flops_xla(base.replace(n_layers=2))
    f8 = _fwd_flops_xla(base.replace(n_layers=8))
    assert f8 < 2.0 * f2, (f2, f8)


@pytest.mark.parametrize("arch", ["minitron-4b", "qwen2-72b"])
def test_forward_flops_match_xla_dense(arch):
    cfg = get_smoke_config(arch).replace(n_layers=1, remat="none",
                                         attn_impl="dense")
    B, S = 2, 64
    got = forward_flops(cfg, B, S)
    want = _fwd_flops_xla(cfg, B, S)
    assert 0.75 * want < got < 1.35 * want, (got, want)


def test_forward_flops_match_xla_moe():
    cfg = get_smoke_config("moonshot-v1-16b-a3b").replace(
        n_layers=1, remat="none", attn_impl="dense")
    B, S = 2, 64
    got = forward_flops(cfg, B, S)
    want = _fwd_flops_xla(cfg, B, S)
    assert 0.6 * want < got < 1.6 * want, (got, want)


def test_forward_flops_match_xla_ssm():
    cfg = get_smoke_config("mamba2-130m").replace(n_layers=1, remat="none")
    cfg = cfg.replace(ssm=cfg.ssm.__class__(
        d_state=cfg.ssm.d_state, d_conv=cfg.ssm.d_conv, expand=cfg.ssm.expand,
        head_dim=cfg.ssm.head_dim, n_groups=cfg.ssm.n_groups, chunk=64))
    B, S = 2, 64                                   # single chunk: trip 1
    got = forward_flops(cfg, B, S)
    want = _fwd_flops_xla(cfg, B, S)
    assert 0.5 * want < got < 1.6 * want, (got, want)


def test_train_flops_match_xla():
    from repro.optim import AdamW, AdamWConfig
    from repro.train.steps import make_train_step
    cfg = get_smoke_config("minitron-4b").replace(n_layers=1, remat="none",
                                                  attn_impl="dense")
    model = Model(cfg)
    B, S = 2, 64
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = jax.eval_shape(lambda: smoke_batch(cfg, batch=B, seq=S))
    opt = AdamW(AdamWConfig())
    ostate = jax.eval_shape(opt.init, params)
    want = _xla_flops(make_train_step(model, opt), params, ostate, batch)
    got = step_cost(cfg, "train", S, B).flops
    assert 0.6 * want < got < 1.5 * want, (got, want)


def test_train_bytes_same_order_as_xla():
    """Bytes are an accounting model, not an HLO count — same order only."""
    cfg = get_smoke_config("minitron-4b").replace(n_layers=1, remat="none",
                                                  attn_impl="dense")
    from repro.optim import AdamW, AdamWConfig
    from repro.train.steps import make_train_step
    model = Model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = jax.eval_shape(lambda: smoke_batch(cfg, batch=2, seq=64))
    opt = AdamW(AdamWConfig())
    ostate = jax.eval_shape(opt.init, params)
    comp = jax.jit(make_train_step(model, opt)).lower(
        params, ostate, batch).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    want = float(ca.get("bytes accessed", 0.0))
    got = step_cost(cfg, "train", 64, 2).hbm_bytes
    assert 0.1 * want < got < 10 * want, (got, want)


def test_decode_cost_scaling_properties():
    """Decode: flops ~ active params; bytes dominated by the KV cache and
    growing linearly with cache length (the decode memory wall)."""
    cfg = get_smoke_config("qwen2-72b")
    c1 = step_cost(cfg, "decode", 1024, 8)
    c2 = step_cost(cfg, "decode", 4096, 8)
    assert c2.hbm_bytes > 2.5 * c1.hbm_bytes       # cache-linear
    from repro.models.config import param_count
    total, active = param_count(cfg)
    assert c1.flops > 2 * active * 8               # ≥ 2·N·B matmul floor


def test_ssm_decode_cache_constant():
    cfg = get_smoke_config("mamba2-130m")
    c1 = step_cost(cfg, "decode", 1024, 8)
    c2 = step_cost(cfg, "decode", 1 << 19, 8)
    assert abs(c1.hbm_bytes - c2.hbm_bytes) / c1.hbm_bytes < 1e-6


def test_train_flops_scale_with_layers_and_tokens():
    cfg = get_smoke_config("gemma-7b").replace(remat="none")
    f1 = step_cost(cfg, "train", 64, 2).flops
    f2 = step_cost(cfg.replace(n_layers=2 * cfg.n_layers), "train", 64, 2).flops
    f3 = step_cost(cfg, "train", 128, 2).flops
    assert f2 > 1.5 * f1                           # layers ↑ ⇒ flops ↑
    assert 1.8 * f1 < f3 < 2.6 * f1                # tokens ×2 ⇒ ≈ ×2 (+attn)
