"""Runtime + scheduler layers over the peer transport (PR 4): direct-mode
collectives replacing credit accounting, device→device PresentEntry
fulfillment, peer-routed wavefront DAGs, and the satellite regressions
(shape-change replacement on a long-lived runtime; exit_data with unsettled
device-ahead write futures)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClusterRuntime, DagTask, DevicePool,
                        HostFunnelTransport, KernelTable, MapSpec, PeerRef,
                        RuntimeConfig, TargetExecutor, wavefront_offload)


def _dp_table():
    table = KernelTable()

    @table.kernel("mse_grads")
    def mse_grads(params, batch):
        def loss(p):
            pred = batch["x"] @ p["w"] + p["b"]
            return jnp.mean((pred - batch["y"]) ** 2)
        return {"grads": jax.grad(loss)(params)}

    return table


def _params(d, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((d, d)), jnp.float32),
            "b": jnp.zeros((d,), jnp.float32)}


def _batches(d, nb, n, seed=1):
    rng = np.random.default_rng(seed)
    return [{"x": jnp.asarray(rng.standard_normal((nb, d)), jnp.float32),
             "y": jnp.asarray(rng.standard_normal((nb, d)), jnp.float32)}
            for _ in range(n)]


# ---------------------------------------------------------------------------
# data_parallel_grads: the ring is real now
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("resident", [True, False])
def test_direct_grads_match_host_mediated(resident):
    d, n = 24, 3
    params, batches = _params(d), _batches(d, 4, n)

    def run(mode):
        rt = ClusterRuntime(RuntimeConfig(n_virtual=n, comm_mode=mode),
                            table=_dp_table())
        g = rt.data_parallel_grads("mse_grads", params, batches,
                                   resident=resident)
        g2 = rt.data_parallel_grads("mse_grads", params, batches,
                                    resident=resident)
        s = rt.cost.summary()
        rt.shutdown()
        return g, g2, s

    gh, gh2, sh = run("host-mediated")
    gd, gd2, sd = run("direct")
    for a, b in ((gd, gh), (gd2, gh2)):
        np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                                   rtol=1e-5, atol=1e-6)
    # two calls: the funnel fetched 2 sums, not 2·D gradient copies
    param_bytes = (d * d + d) * 4
    assert sd["bytes_from"] == 2 * param_bytes
    assert sh["bytes_from"] == 2 * n * param_bytes
    assert sd["bytes_peer"] > 0 and sh["bytes_peer"] == 0


def test_direct_grads_int8_wire_within_block_bound():
    d, n = 32, 4
    params, batches = _params(d), _batches(d, 4, n)
    rt = ClusterRuntime(RuntimeConfig(n_virtual=n, comm_mode="host-mediated"),
                        table=_dp_table())
    ref = rt.data_parallel_grads("mse_grads", params, batches)
    rt.shutdown()
    rt = ClusterRuntime(RuntimeConfig(n_virtual=n, comm_mode="direct",
                                      compress=True), table=_dp_table())
    g = rt.data_parallel_grads("mse_grads", params, batches)
    s = rt.cost.summary()
    rt.shutdown()
    err = np.abs(np.asarray(g["w"]) - np.asarray(ref["w"])).max()
    scale = np.abs(np.asarray(ref["w"])).max()
    assert err <= scale / 64, (err, scale)
    # the ring moved compressed messages: block-int8 is ~4x smaller
    raw_ring = n * (n - 1) * (d * d + d) * 4
    assert s["bytes_peer"] < 0.4 * raw_ring


def test_direct_path_records_no_adjustments():
    """Acceptance: the direct path's bytes are all real messages — the
    credit-based ring (`record_adjustment`) is retired."""
    n = 3
    params, batches = _params(16), _batches(16, 2, n)
    rt = ClusterRuntime(RuntimeConfig(n_virtual=n, comm_mode="direct"),
                        table=_dp_table())
    rt.data_parallel_grads("mse_grads", params, batches)
    for _ in range(4):
        rt.data_parallel_step("mse_grads", params, batches, sync_every=2)
    assert rt.cost.adjustments == []
    assert rt.cost.bytes_peer() > 0
    rt.shutdown()


# ---------------------------------------------------------------------------
# satellite: shape-change replacement on a long-lived runtime
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["host-mediated", "direct"])
def test_param_shape_change_on_long_lived_runtime(mode):
    """Regression (PR 4 satellite): swapping in a new model shape under the
    same resident-entry name must replace the environment — the old code's
    except-branch freed an entry it never entered under that name."""
    rt = ClusterRuntime(RuntimeConfig(n_virtual=2, comm_mode=mode),
                        table=_dp_table())
    for d in (16, 24, 16):                       # grow, then shrink back
        params, batches = _params(d), _batches(d, 2, 2)
        g = rt.data_parallel_grads("mse_grads", params, batches)
        assert np.asarray(g["w"]).shape == (d, d)
        # the entry now resident is the new shape, under the runtime's
        # namespaced name
        ent = rt.pool.present[0].get("_dpg_params")
        assert ent is not None and ent.specs[1].shape == (d, d)
    rt.pool.sync()
    for dev in range(2):
        assert (sorted(rt.pool.mirrors[dev].live_handles())
                == sorted(rt.pool.devices[dev].store.live_handles())), dev
    rt.shutdown()


def test_dp_grads_does_not_clobber_user_params_environment():
    """The audit behind the satellite: the trainer pins under `_dpg_params`,
    so a user's own environment named "params" survives a shape change that
    triggers the replacement path (the old code exited — and could free —
    the user's entry)."""
    rt = ClusterRuntime(RuntimeConfig(n_virtual=2), table=_dp_table())
    mine = jnp.arange(7.0, dtype=jnp.float32)
    for d in range(2):
        rt.ex.enter_data(d, "user", params=mine)
    rt.data_parallel_grads("mse_grads", _params(16), _batches(16, 2, 2))
    rt.data_parallel_grads("mse_grads", _params(24), _batches(24, 2, 2))
    for d in range(2):
        ent = rt.pool.present[d].get("params")
        assert ent is not None and ent.refcount == 1
        np.testing.assert_array_equal(
            np.asarray(rt.ex.fetch_resident(d, "params")), np.asarray(mine))
    for d in range(2):
        rt.ex.exit_data(d, "params")
    rt.shutdown()


# ---------------------------------------------------------------------------
# data_parallel_step: the direct sync path (the ROADMAP open item)
# ---------------------------------------------------------------------------
def test_dps_direct_sync_bit_identical_with_fewer_funnel_bytes():
    d, n, steps, sync_every = 16, 4, 8, 4
    params, batches = _params(d), _batches(d, 2, n)

    def run(mode):
        rt = ClusterRuntime(RuntimeConfig(n_virtual=n, comm_mode=mode),
                            table=_dp_table())
        p = None
        for _ in range(steps):
            p = rt.data_parallel_step("mse_grads", params, batches,
                                      sync_every=sync_every)
        s = rt.cost.summary()
        rt.shutdown()
        return p, s

    ph, sh = run("host-mediated")
    pd, sd = run("direct")
    # bit-identical: the root reduces in the host's association order
    np.testing.assert_array_equal(np.asarray(ph["w"]), np.asarray(pd["w"]))
    np.testing.assert_array_equal(np.asarray(ph["b"]), np.asarray(pd["b"]))
    # each sync: host-mediated fetches D copies and pushes D means; direct
    # fetches ONE mean and pushes nothing over the funnel
    param_bytes = (d * d + d) * 4
    syncs = steps // sync_every
    assert sh["bytes_from"] == syncs * n * param_bytes
    assert sd["bytes_from"] == syncs * param_bytes
    assert sh["bytes_from"] >= 2 * sd["bytes_from"]
    assert sd["bytes_to"] < sh["bytes_to"]              # no sync re-broadcast
    assert sd["bytes_peer"] == syncs * 2 * (n - 1) * param_bytes


def test_dps_direct_forced_sync_and_handle_agreement():
    rt = ClusterRuntime(RuntimeConfig(n_virtual=3, comm_mode="direct"),
                        table=_dp_table())
    d = 16
    params = {"w": jnp.eye(d), "b": jnp.zeros((d,))}
    batches = [{"x": jnp.ones((2, d)), "y": jnp.full((2, d), float(i))}
               for i in range(3)]
    for _ in range(5):
        rt.data_parallel_step("mse_grads", params, batches, sync_every=2)
    mean = rt.data_parallel_sync()
    views = [rt.ex.fetch_resident(dev, "_dps_params") for dev in range(3)]
    for v in views:                       # broadcast delivered the same mean
        np.testing.assert_array_equal(np.asarray(v["w"]),
                                      np.asarray(mean["w"]))
    rt.pool.sync()
    for dev in range(3):
        assert (sorted(rt.pool.mirrors[dev].live_handles())
                == sorted(rt.pool.devices[dev].store.live_handles())), dev
    rt.shutdown()


# ---------------------------------------------------------------------------
# mediary: device→device PresentEntry fulfillment
# ---------------------------------------------------------------------------
def _ex_pool(n=2):
    table = KernelTable()
    table.register("bump", lambda a: {"a": a + 1})
    table.register("gen", lambda x: {"out": x @ x})
    table.register("consume", lambda lu, a: {"out": lu + 2 * a})
    pool = DevicePool.virtual(n, table=table)
    return pool, TargetExecutor(pool)


def test_propagate_resident_device_ahead_skips_host():
    """A device-ahead entry reaches a peer still device-ahead: the bytes
    moved peer-to-peer, the host funnel saw none of them, and no host
    reconciliation happened on the way."""
    pool, ex = _ex_pool(2)
    v0 = jnp.zeros(8, jnp.float32)
    ex.ensure_resident(0, a=v0)
    for _ in range(3):                       # device copy advances past host
        ex.target("bump", 0, MapSpec(present=("a",), device_out=("a",)))
    funnel_before = pool.cost.bytes_moved()
    ex.propagate_resident(0, 1, "a")
    ent = pool.present[1].get("a")
    assert ent is not None and ent.device_ahead
    assert pool.cost.bytes_moved() == funnel_before      # zero funnel bytes
    assert pool.cost.bytes_peer() == 8 * 4
    # the peer's copy is the advanced content, host view still reconciles
    np.testing.assert_allclose(np.asarray(ex.fetch_resident(1, "a")), 3.0)
    ex.exit_data(0, "a")
    ex.exit_data(1, "a")
    pool.sync()
    for d in range(2):
        assert pool.devices[d].store.live_handles() == [], d
    pool.stop_all()


def test_propagate_resident_over_host_funnel_transport():
    pool, ex = _ex_pool(2)
    ex.ensure_resident(0, a=jnp.arange(4.0, dtype=jnp.float32))
    before = pool.cost.bytes_moved()
    ex.propagate_resident(0, 1, "a", transport=HostFunnelTransport())
    np.testing.assert_allclose(np.asarray(ex.fetch_resident(1, "a")),
                               np.arange(4.0))
    # paper topology: the same fulfillment costs a fetch + a re-send
    assert pool.cost.bytes_moved() - before >= 2 * 4 * 4
    assert pool.cost.bytes_peer() == 0
    ex.exit_data(0, "a")
    ex.exit_data(1, "a")
    pool.stop_all()


def test_propagate_resident_structure_mismatch_raises():
    pool, ex = _ex_pool(2)
    ex.ensure_resident(0, a=jnp.ones(4))
    ex.ensure_resident(1, a=jnp.ones(5))
    with pytest.raises(ValueError, match="structure differs"):
        ex.propagate_resident(0, 1, "a")
    ex.exit_data(0, "a")
    ex.exit_data(1, "a")
    pool.stop_all()


# ---------------------------------------------------------------------------
# satellite: exit_data while a device-ahead entry has unsettled write_futs
# ---------------------------------------------------------------------------
def test_exit_data_with_unsettled_device_ahead_write_futs():
    """The previously untested failure path: exiting an entry whose
    device-side writeback has not run yet (exactly the state a nowait
    ``device_out`` region leaves behind — marked ahead, write futures
    pending in the stream).  The FREE is a stream writer of the same
    handle, so it must run after the writeback; nothing leaks, nothing
    raises, and the late writeback still lands in a live slot."""
    pool, ex = _ex_pool(1)
    ex.ensure_resident(0, a=jnp.zeros(8, jnp.float32))
    gate = threading.Event()
    pool._submit(0, gate.wait)               # hold the device stream
    # the device_out epilogue, as _writeback_ahead performs it: mark ahead
    # and queue the on-device writeback in one env-lock critical section
    with pool.env_locks[0]:
        ent = pool.present[0].get("a")
        h = ent.handles[0]
        ent.device_ahead = True
        ent.version += 1
        ent.write_futs = [pool.transfer_to_writeback(
            0, h, jnp.full(8, 3.0, jnp.float32))]
        wf = list(ent.write_futs)
    assert not wf[0].done()                  # genuinely unsettled
    ex.exit_data(0, "a")                     # free with the writeback pending
    assert pool.present[0].get("a") is None
    gate.set()
    pool.sync()                              # writeback then FREE, no error
    assert pool.devices[0].store.live_handles() == []
    assert pool.mirrors[0].live_handles() == []
    pool.stop_all()


# ---------------------------------------------------------------------------
# scheduler: peer-routed wavefront
# ---------------------------------------------------------------------------
def _fanout_dag(mat, ams):
    sds = jax.ShapeDtypeStruct(mat.shape, mat.dtype)
    tasks = [DagTask("p", "gen", (),
                     lambda deps: MapSpec(to={"x": mat}, from_={"out": sds}))]
    for i, a in enumerate(ams):
        tasks.append(DagTask(
            f"c{i}", "consume", ("p",),
            (lambda a=a: lambda deps: MapSpec(
                to={"lu": deps["p"], "a": a}, from_={"out": sds}))()))
    return tasks


def _run_wave(peer, nowait=True, n_dev=2):
    rng = np.random.default_rng(0)
    mat = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    ams = [jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
           for _ in range(5)]
    pool, ex = _ex_pool(n_dev)
    res = wavefront_offload(ex, _fanout_dag(mat, ams), nowait=nowait,
                            peer=peer)
    s = pool.cost.summary()
    for d in range(n_dev):                  # every entry released
        assert len(pool.present[d]) == 0, pool.present[d].names()
    pool.sync()
    for d in range(n_dev):
        assert pool.devices[d].store.live_handles() == [], d
        assert pool.mirrors[d].live_handles() == [], d
    pool.stop_all()
    return res, s


@pytest.mark.parametrize("nowait", [False, True])
def test_peer_wavefront_matches_host_mediated(nowait):
    r_host, _ = _run_wave(peer=False, nowait=nowait)
    r_peer, _ = _run_wave(peer=True, nowait=nowait)
    assert r_host.keys() == r_peer.keys()
    for k in r_host:
        np.testing.assert_allclose(np.asarray(r_peer[k]),
                                   np.asarray(r_host[k]), rtol=1e-5,
                                   atol=1e-6)


def test_peer_wavefront_routes_edges_off_the_funnel():
    _, s_host = _run_wave(peer=False)
    _, s_peer = _run_wave(peer=True)
    # the pivot's fan-out edges stop crossing the host: strictly fewer
    # to-bytes, dependencies ride the peer fabric, final results still
    # fetched exactly once each
    assert s_peer["bytes_to"] < s_host["bytes_to"], (s_peer, s_host)
    assert s_peer["bytes_peer"] > 0 and s_host["bytes_peer"] == 0
    assert s_peer["bytes_from"] == s_host["bytes_from"]


def test_peer_wavefront_failure_releases_entries():
    pool, ex = _ex_pool(2)
    table = pool.table
    table.register("boomk", lambda x: (_ for _ in ()).throw(
        ValueError("injected kernel failure")))
    rng = np.random.default_rng(1)
    mat = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    sds = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    tasks = _fanout_dag(mat, [mat + 1, mat + 2])
    tasks.append(DagTask("bad", "boomk", ("p",),
                         lambda deps: MapSpec(to={"x": deps["p"]},
                                              from_={"out": sds})))
    with pytest.raises(ValueError, match="injected"):
        wavefront_offload(ex, tasks, nowait=True, peer=True)
    for d in range(2):
        assert len(pool.present[d]) == 0, pool.present[d].names()
    pool.sync()
    for d in range(2):
        assert pool.devices[d].store.live_handles() == [], d
    pool.stop_all()


def test_peer_ref_misuse_raises():
    pool, ex = _ex_pool(2)
    sds = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    mat = jnp.eye(4)
    tasks = [DagTask("p", "gen", (),
                     lambda deps: MapSpec(to={"x": mat}, from_={"out": sds})),
             DagTask("c", "bump", ("p",),
                     lambda deps: MapSpec(tofrom={"a": deps["p"]}))]
    with pytest.raises(TypeError, match="to= clause"):
        wavefront_offload(ex, tasks, nowait=False, peer=True)
    pool.stop_all()
