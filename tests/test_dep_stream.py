"""Dependency-aware device streams (PR 3 tentpole): per-handle command
ordering, nowait x resident wavefronts, device-resident optimizer steps,
and the data-environment failure-path fixes."""
import concurrent.futures as _cf
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClusterRuntime, DagTask, DevicePool, KernelTable,
                        MapSpec, RuntimeConfig, TargetExecutor,
                        wavefront_offload)
from repro.optim import AdamW, AdamWConfig


def _make_ex(n_dev=2):
    table = KernelTable()

    @table.kernel("axpb")
    def axpb(a, b):
        return {"out": a + b}

    @table.kernel("gen")
    def gen(x):
        return {"out": x @ x}

    @table.kernel("consume")
    def consume(lu, a):
        return {"out": lu + 2 * a}

    @table.kernel("boomk")
    def boomk(x):
        raise ValueError("injected kernel failure")

    @table.kernel("ident")
    def ident(x):
        return {"out": x}

    @table.kernel("bump")
    def bump(a):
        return {"a": a + 1}

    @table.kernel("use_global")
    def use_global(g, x):
        return {"out": g + x}

    pool = DevicePool.virtual(n_dev, table=table)
    return pool, TargetExecutor(pool)


def _fanout_dag(mat, ams):
    """One producer, N consumers of its output — sparselu's pivot fan-out."""
    sds = jax.ShapeDtypeStruct(mat.shape, mat.dtype)
    tasks = [DagTask("p", "gen", (),
                     lambda deps: MapSpec(to={"x": mat}, from_={"out": sds}))]
    for i, a in enumerate(ams):
        tasks.append(DagTask(
            f"c{i}", "consume", ("p",),
            (lambda a=a: lambda deps: MapSpec(
                to={"lu": deps["p"], "a": a}, from_={"out": sds}))()))
    return tasks


# ---------------------------------------------------------------------------
# nowait x resident: identical results, strictly fewer to-bytes
# ---------------------------------------------------------------------------
def _run_wavefront(nowait, resident, n_dev=2):
    rng = np.random.default_rng(0)
    mat = jnp.asarray(rng.standard_normal((24, 24)), jnp.float32)
    ams = [jnp.asarray(rng.standard_normal((24, 24)), jnp.float32)
           for _ in range(6)]
    pool, ex = _make_ex(n_dev)
    res = wavefront_offload(ex, _fanout_dag(mat, ams),
                            nowait=nowait, resident=resident)
    to_bytes = pool.cost.bytes_moved("to")
    # wave-resident exit path: every per-wave pin is released
    for d in range(n_dev):
        assert len(pool.present[d]) == 0, pool.present[d].names()
    pool.sync()
    for d in range(n_dev):
        assert pool.devices[d].store.live_handles() == [], d
        assert pool.mirrors[d].live_handles() == [], d
    return res, to_bytes


def test_nowait_resident_no_longer_raises_and_matches_serial():
    r_serial, _ = _run_wavefront(nowait=False, resident=False)
    r_conc, _ = _run_wavefront(nowait=True, resident=True)
    assert r_serial.keys() == r_conc.keys()
    for k in r_serial:
        np.testing.assert_allclose(r_conc[k], r_serial[k], rtol=1e-6)


def test_nowait_resident_moves_fewer_to_bytes():
    _, plain = _run_wavefront(nowait=True, resident=False)
    _, res = _run_wavefront(nowait=True, resident=True)
    assert res < plain, (res, plain)     # shared pivot crossed once per device


def test_mid_wave_failure_releases_every_pin():
    rng = np.random.default_rng(1)
    mat = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    sds = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    tasks = _fanout_dag(mat, [mat + 1, mat + 2, mat + 3])
    tasks.append(DagTask("bad", "boomk", ("p",),
                         lambda deps: MapSpec(to={"x": deps["p"]},
                                              from_={"out": sds})))
    pool, ex = _make_ex(2)
    with pytest.raises(ValueError, match="injected"):
        wavefront_offload(ex, tasks, nowait=True, resident=True)
    for d in range(2):
        assert len(pool.present[d]) == 0
    pool.sync()
    for d in range(2):
        assert pool.devices[d].store.live_handles() == [], d


def test_mid_dispatch_failure_joins_launched_regions_and_releases_pins():
    """A later task's make_maps raising mid-wave must not leave the already
    launched regions running unjoined or their pins held."""
    rng = np.random.default_rng(2)
    mat = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    sds = jax.ShapeDtypeStruct((8, 8), jnp.float32)

    def bad_maps(deps):
        raise RuntimeError("injected make_maps failure")

    tasks = [DagTask("ok", "gen", (),
                     lambda deps: MapSpec(to={"x": mat}, from_={"out": sds})),
             DagTask("bad", "gen", (), bad_maps)]
    pool, ex = _make_ex(2)
    with pytest.raises(RuntimeError, match="injected make_maps"):
        wavefront_offload(ex, tasks, nowait=True, resident=True)
    with ex._inflight_lock:
        assert ex._inflight == []        # the launched region was retired
    for d in range(2):
        assert len(pool.present[d]) == 0
    pool.sync()
    for d in range(2):
        assert pool.devices[d].store.live_handles() == [], d


def test_same_name_in_two_clauses_reuses_one_ticket():
    """present + tofrom naming the same resident buffer must not leak an
    open reader ticket (a leaked one wedges the writeback forever)."""
    pool, ex = _make_ex(1)
    v = jnp.full(4, 2.0, jnp.float32)
    ex.ensure_resident(0, a=v)
    out = ex.target("bump", 0, MapSpec(present=("a",), tofrom={"a": v}))
    np.testing.assert_allclose(out["a"], 3.0)
    pool.sync(0)
    # every registered reader settled: no open ticket survived the region
    assert all(f.done() for futs in pool._readers[0].values() for f in futs)
    ex.exit_data(0, "a")
    pool.sync()
    assert pool.devices[0].store.live_handles() == []


# ---------------------------------------------------------------------------
# producer/consumer ordering: two nowait regions share one resident name
# ---------------------------------------------------------------------------
def _wait_for_exec(pool, tag, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        with pool._trace_lock:
            if any(c.op == "EXEC" and c.tag == tag for c in pool.trace):
                return
        time.sleep(0.005)
    raise AssertionError(f"EXEC {tag!r} never issued")


def test_concurrent_regions_on_shared_resident_name_are_handle_ordered():
    """Region A matches version 1 of a resident buffer; a refresh to
    version 2 and region B are issued while A is still in flight.  The
    stream must order A's EXEC before the refresh XFER_TO before B's EXEC —
    per-handle producer/consumer ordering, not whole-queue serialization —
    and each region must compute with the version it matched."""
    pool, ex = _make_ex(1)
    v1 = jnp.full(8, 1.0, jnp.float32)
    ex.ensure_resident(0, a=v1)
    handle = pool.present[0].get("a").handles[0]
    gate = threading.Event()
    pool._submit(0, gate.wait)           # stall execution, not issue
    sds = jax.ShapeDtypeStruct((8,), jnp.float32)
    fut_a = ex.target("axpb", 0, MapSpec(to={"a": v1, "b": jnp.zeros(8)},
                                         from_={"out": sds}),
                      nowait=True, tag="regA")
    _wait_for_exec(pool, "regA")         # A matched v1 and issued its EXEC
    v2 = jnp.full(8, 5.0, jnp.float32)
    ex.ensure_resident(0, a=v2)          # refresh: a writer of A's handle
    fut_b = ex.target("axpb", 0, MapSpec(to={"a": v2, "b": jnp.zeros(8)},
                                         from_={"out": sds}),
                      nowait=True, tag="regB")
    _wait_for_exec(pool, "regB")
    gate.set()
    np.testing.assert_allclose(fut_a.result()["out"], 1.0)   # matched v1
    np.testing.assert_allclose(fut_b.result()["out"], 5.0)   # matched v2
    ex.exit_data(0, "a")
    pool.sync()
    stream = list(pool.stream_traces[0])
    exec_a = next(i for i, c in enumerate(stream)
                  if c.op == "EXEC" and c.tag == "regA")
    exec_b = next(i for i, c in enumerate(stream)
                  if c.op == "EXEC" and c.tag == "regB")
    refresh = [i for i, c in enumerate(stream)
               if c.op == "XFER_TO" and c.handle == handle
               and c.tag == "resident:a"]
    assert len(refresh) == 2             # initial enter + the v2 refresh
    assert exec_a < refresh[1] < exec_b, (exec_a, refresh, exec_b)
    assert handle in stream[exec_a].reads and handle in stream[exec_b].reads


# ---------------------------------------------------------------------------
# drain: an early failure must not retire still-running futures
# ---------------------------------------------------------------------------
def test_drain_waits_for_all_futures_to_settle():
    pool, ex = _make_ex(2)
    sds = jax.ShapeDtypeStruct((4,), jnp.float32)
    gate = threading.Event()
    pool._submit(1, gate.wait)           # hold device 1's stream
    slow = ex.target("ident", 1, MapSpec(to={"x": jnp.ones(4)},
                                         from_={"out": sds}), nowait=True)
    fast = ex.target("boomk", 0, MapSpec(to={"x": jnp.ones(4)},
                                         from_={"out": sds}), nowait=True)
    _cf.wait([fast._fut])                # the failure has settled
    seen = {}

    def run_drain():
        try:
            ex.drain([fast, slow])
        except ValueError as e:
            seen["error"] = e
            seen["slow_settled"] = slow.done()

    t = threading.Thread(target=run_drain)
    t.start()
    t.join(0.5)
    assert t.is_alive()                  # drain holds: slow has not settled
    gate.set()
    t.join(10)
    assert not t.is_alive()
    assert "injected" in str(seen["error"])
    assert seen["slow_settled"] is True  # retired only once everything settled
    with ex._inflight_lock:
        assert ex._inflight == []


# ---------------------------------------------------------------------------
# data-environment failure paths
# ---------------------------------------------------------------------------
def test_enter_data_partial_failure_frees_allocations():
    """A later leaf failing mid-enter must free the handles already made."""
    pool, ex = _make_ex(1)
    with pytest.raises(TypeError):
        ex.enter_data(0, a={"x": jnp.ones(4), "y": "not-an-array"})
    assert "a" not in pool.present[0]
    pool.sync(0)
    assert pool.devices[0].store.live_handles() == []
    assert pool.mirrors[0].live_handles() == []


def test_install_global_after_ensure_resident():
    """First-fit handles diverge across devices once a buffer is pinned on
    one of them; install_global must track per-device handles, not assert."""
    pool, ex = _make_ex(3)
    ex.ensure_resident(0, keep=jnp.ones(4))          # device 0's slot 0 taken
    pool.install_global("g", jnp.full(8, 2.0, jnp.float32))
    assert pool.globals["g"][0] != pool.globals["g"][1]
    sds = jax.ShapeDtypeStruct((8,), jnp.float32)
    for d in range(3):                               # lookup works everywhere
        out = ex.target("use_global", d, MapSpec(
            to={"x": jnp.ones(8)}, from_={"out": sds}, use_globals=("g",)))
        np.testing.assert_allclose(out["out"], 3.0)
    # re-install stays idempotent with divergent handles
    pool.install_global("g", jnp.full(8, 9.0, jnp.float32))
    out = ex.target("use_global", 1, MapSpec(
        to={"x": jnp.ones(8)}, from_={"out": sds}, use_globals=("g",)))
    np.testing.assert_allclose(out["out"], 10.0)
    ex.exit_data(0, "keep")
    pool.sync()
    for d in range(3):                               # mirror/store agreement
        assert (sorted(pool.mirrors[d].live_handles())
                == sorted(pool.devices[d].store.live_handles())), d


# ---------------------------------------------------------------------------
# present / device_out maps
# ---------------------------------------------------------------------------
def test_present_map_requires_residency():
    pool, ex = _make_ex(1)
    with pytest.raises(KeyError, match="not resident"):
        ex.target("bump", 0, MapSpec(present=("a",), device_out=("a",)))


def test_device_out_keeps_result_on_device():
    pool, ex = _make_ex(1)
    v0 = jnp.zeros(8, jnp.float32)
    ex.ensure_resident(0, a=v0)
    before = (pool.cost.bytes_moved("to"), pool.cost.bytes_moved("from"))
    for _ in range(3):
        ex.target("bump", 0, MapSpec(present=("a",), device_out=("a",)))
    # three on-device updates moved zero bytes either way
    assert (pool.cost.bytes_moved("to"), pool.cost.bytes_moved("from")) == before
    ent = pool.present[0].get("a")
    assert ent.device_ahead and ent.refcount == 1
    # a device-ahead entry must not serve a host-value match
    sds = jax.ShapeDtypeStruct((8,), jnp.float32)
    out = ex.target("axpb", 0, MapSpec(to={"a": v0, "b": jnp.zeros(8)},
                                       from_={"out": sds}))
    np.testing.assert_allclose(out["out"], 0.0)      # host value, not device's
    fetched = ex.fetch_resident(0, "a")
    np.testing.assert_allclose(fetched, 3.0)
    assert not pool.present[0].get("a").device_ahead
    ex.exit_data(0, "a")
    pool.sync()
    assert pool.devices[0].store.live_handles() == []


# ---------------------------------------------------------------------------
# device-resident optimizer: data_parallel_step
# ---------------------------------------------------------------------------
def _dp_table():
    table = KernelTable()

    @table.kernel("mse_grads")
    def mse_grads(params, batch):
        def loss(p):
            pred = batch["x"] @ p["w"] + p["b"]
            return jnp.mean((pred - batch["y"]) ** 2)
        return {"grads": jax.grad(loss)(params)}

    return table


def test_data_parallel_step_cuts_from_traffic_3x_with_same_numerics():
    """Acceptance: 8 local steps with sync_every=4 fetch parameters twice
    instead of gradients eight times (4x fewer from-bytes) and, with every
    device on the same batch, land on the same parameters as per-step
    data_parallel_grads + a host AdamW update."""
    d, steps, n_dev = 32, 8, 2
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((d, d)), jnp.float32),
              "b": jnp.zeros((d,), jnp.float32)}
    batch = {"x": jnp.asarray(rng.standard_normal((4, d)), jnp.float32),
             "y": jnp.asarray(rng.standard_normal((4, d)), jnp.float32)}

    rt = ClusterRuntime(RuntimeConfig(n_virtual=n_dev), table=_dp_table())
    dps_params = None
    for _ in range(steps):
        dps_params = rt.data_parallel_step("mse_grads", params,
                                           [batch] * n_dev, sync_every=4)
    dps_from = rt.cost.bytes_moved("from")
    rt.shutdown()

    rt2 = ClusterRuntime(RuntimeConfig(n_virtual=n_dev), table=_dp_table())
    opt = AdamW(AdamWConfig())
    state, host_params = opt.init(params), params
    for _ in range(steps):
        g = rt2.data_parallel_grads("mse_grads", host_params, [batch] * n_dev)
        host_params, state, _ = opt.update(g, state, host_params)
    base_from = rt2.cost.bytes_moved("from")
    rt2.shutdown()

    assert base_from >= 3 * dps_from, (base_from, dps_from)
    np.testing.assert_allclose(np.asarray(dps_params["w"]),
                               np.asarray(host_params["w"]),
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dps_params["b"]),
                               np.asarray(host_params["b"]),
                               rtol=2e-4, atol=1e-5)


def test_data_parallel_step_and_grads_namespaces_do_not_collide():
    """The optimizer's resident state lives under _dps_-prefixed names, so
    interleaving data_parallel_grads (which pins its own "params") must
    neither clobber the device-advanced parameters nor free them."""
    d = 16
    rt = ClusterRuntime(RuntimeConfig(n_virtual=2), table=_dp_table())
    params = {"w": jnp.eye(d), "b": jnp.zeros((d,))}
    batches = [{"x": jnp.ones((2, d)), "y": jnp.zeros((2, d))}] * 2
    rt.data_parallel_step("mse_grads", params, batches, sync_every=2)
    rt.data_parallel_step("mse_grads", params, batches, sync_every=2)
    synced = rt._dps["host_params"]
    rt.data_parallel_grads("mse_grads", params, batches)   # pins "params"
    after = rt.data_parallel_step("mse_grads", params, batches, sync_every=2)
    # the interleaved grads call did not reset the optimizer's trajectory
    assert rt._dps["step"] == 3
    assert after is synced                     # no sync on step 3
    np.testing.assert_allclose(rt.ex.fetch_resident(0, "_dps_count"), 3.0)
    rt.shutdown()


def test_data_parallel_step_interleaves_with_handle_agreement():
    """Local steps + syncs leave mirror and store agreeing on every device."""
    rt = ClusterRuntime(RuntimeConfig(n_virtual=3), table=_dp_table())
    d = 16
    params = {"w": jnp.eye(d), "b": jnp.zeros((d,))}
    batches = [{"x": jnp.ones((2, d)), "y": jnp.full((2, d), float(i))}
               for i in range(3)]
    for _ in range(5):
        rt.data_parallel_step("mse_grads", params, batches, sync_every=2)
    rt.data_parallel_sync()
    rt.pool.sync()
    for dev in range(3):
        assert (sorted(rt.pool.mirrors[dev].live_handles())
                == sorted(rt.pool.devices[dev].store.live_handles())), dev
    rt.shutdown()
