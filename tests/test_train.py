"""Training substrate: optimizer math, microbatch equivalence, loss descent,
gradient compression contracts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container image lacks hypothesis
    from _hypothesis_shim import given, settings, st

from repro.configs.registry import get_smoke_config, smoke_batch
from repro.core import compression as comp
from repro.data import DataConfig, SyntheticLM
from repro.models.model import Model
from repro.optim import AdamW, AdamWConfig
from repro.optim.schedule import cosine_warmup
from repro.train.steps import make_train_step


# ---------------------------------------------------------------------------
# AdamW against a hand-rolled numpy oracle
# ---------------------------------------------------------------------------
def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.1,
                      clip_norm=0.0)
    opt = AdamW(cfg)
    p = {"w": jnp.asarray([[1.0, -2.0], [3.0, 0.5]])}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.0]])}
    state = opt.init(p)
    new_p, state, _ = opt.update(g, state, p)

    # numpy reference (step 1)
    gw = np.asarray(g["w"]); pw = np.asarray(p["w"])
    m = (1 - cfg.b1) * gw
    v = (1 - cfg.b2) * gw * gw
    mhat = m / (1 - cfg.b1)
    vhat = v / (1 - cfg.b2)
    want = pw - cfg.lr * (mhat / (np.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pw)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)


def test_adamw_grad_clipping():
    opt = AdamW(AdamWConfig(lr=1e-2, clip_norm=1.0))
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}                  # norm 200 >> 1
    state = opt.init(p)
    _, _, metrics = opt.update(g, state, p)
    assert float(metrics["grad_norm"]) > 100.0     # reported pre-clip


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_state_dtypes_step(state_dtype):
    opt = AdamW(AdamWConfig(lr=1e-3, state_dtype=state_dtype))
    p = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 16))}
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (16, 16)) * 0.1}
    state = opt.init(p)
    for _ in range(3):
        p, state, m = opt.update(g, state, p)
    assert bool(jnp.isfinite(p["w"]).all())


def test_cosine_warmup_schedule():
    lr = cosine_warmup(1.0, warmup_steps=10, total_steps=110, min_ratio=0.1)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-5)
    assert float(lr(5)) == pytest.approx(0.5, rel=1e-5)
    np.testing.assert_allclose(float(lr(110)), 0.1, rtol=1e-4)
    assert float(lr(60)) < float(lr(20))


# ---------------------------------------------------------------------------
# microbatching == big batch (gradient accumulation correctness)
# ---------------------------------------------------------------------------
def test_microbatch_equivalence():
    cfg = get_smoke_config("minitron-4b").replace(remat="none",
                                                  param_dtype="float32",
                                                  compute_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, batch=4, seq=16)
    opt = AdamW(AdamWConfig(lr=1e-3))
    s1 = jax.jit(make_train_step(model, opt, microbatches=1))
    s2 = jax.jit(make_train_step(model, opt, microbatches=2))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-5


# ---------------------------------------------------------------------------
# end-to-end descent on the synthetic pipeline
# ---------------------------------------------------------------------------
def test_loss_decreases_on_synthetic_data():
    cfg = get_smoke_config("mamba2-130m")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(AdamWConfig(lr=3e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq=32, global_batch=8),
                       process_index=0, process_count=1)
    losses = []
    for i in range(30):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


# ---------------------------------------------------------------------------
# gradient compression (int8 + error feedback)
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 400), st.floats(0.01, 100.0))
def test_compress_roundtrip_bounded_error(n, scale):
    x = np.linspace(-scale, scale, n, dtype=np.float32)
    c = comp.compress(jnp.asarray(x))
    y = np.asarray(comp.decompress(c, x.shape))
    # int8 with per-block scale: error ≤ scale_block/2 ≤ max|block|/254*2
    assert np.abs(y - x).max() <= scale / 127.0 + 1e-6


def test_error_feedback_telescopes():
    """Over T rounds, Σ decompressed == Σ inputs − final residual (exactly)."""
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.standard_normal(37).astype(np.float32))
          for _ in range(8)]
    residual = comp.ef_init(xs[0])
    total_sent = jnp.zeros(37)
    for x in xs:
        c, residual = comp.ef_compress(x, residual)
        total_sent = total_sent + comp.decompress(c, x.shape)
    want = sum(np.asarray(x) for x in xs)
    np.testing.assert_allclose(np.asarray(total_sent) + np.asarray(residual),
                               want, rtol=1e-5, atol=1e-5)


def test_compressed_bytes_are_4x_smaller():
    x = jnp.ones((1024,))
    c = comp.compress(x)
    assert comp.compressed_nbytes(c) < 0.3 * x.size * 4


# ---------------------------------------------------------------------------
# host-mediated vs direct DP fabric (ClusterRuntime)
# ---------------------------------------------------------------------------
def test_data_parallel_grads_modes_agree():
    """Both comm topologies produce the same mean gradient; the funnel costs
    more host traffic (the paper's central finding, at unit-test scale)."""
    from repro.core import ClusterRuntime, RuntimeConfig, KernelTable

    table = KernelTable()

    @table.kernel("gradk")
    def gradk(params, batch):
        # grad of 0.5*||w*x - y||² wrt w
        w = params["w"]
        x, y = batch["x"], batch["y"]
        return {"grads": {"w": (w * x - y) * x}}

    batches = [{"x": jnp.full(4, float(i + 1)), "y": jnp.ones(4)}
               for i in range(3)]
    params = {"w": jnp.full(4, 2.0)}

    def run(mode):
        rt = ClusterRuntime(RuntimeConfig(n_virtual=3, comm_mode=mode),
                            table=table)
        g = rt.data_parallel_grads("gradk", params, batches)
        stats = rt.cost.summary()
        rt.shutdown()
        return g, stats

    g_host, s_host = run("host-mediated")
    g_direct, s_direct = run("direct")
    np.testing.assert_allclose(np.asarray(g_host["w"]),
                               np.asarray(g_direct["w"]), rtol=1e-6)
    want = sum(np.asarray((params["w"] * b["x"] - b["y"]) * b["x"])
               for b in batches) / 3
    np.testing.assert_allclose(np.asarray(g_direct["w"]), want, rtol=1e-6)
    # the host funnel moves ≥ direct mode's bytes through the host NIC
    assert s_host["bytes_from"] >= s_direct["bytes_from"]
