import os
import sys

# Make `import repro` work regardless of how pytest is invoked.
# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benches must see
# the real single CpuDevice; only launch/dryrun.py forces 512 devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
