"""Serving correctness: the KV/SSM-cache decode path must reproduce the
full-sequence forward pass (teacher-forced), per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.model import Model
from repro.serve import Request, ServeConfig, ServeEngine


@pytest.mark.parametrize("arch", ["qwen2-72b", "mamba2-130m", "zamba2-2.7b",
                                  "gemma3-4b"])
def test_decode_matches_forward(arch):
    """prefill(prompt) + decode(t) logits == forward(prompt+t) logits."""
    cfg = get_smoke_config(arch).replace(remat="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    B, S_prompt, n_new = 2, 12, 4
    tokens = jax.random.randint(rng, (B, S_prompt + n_new), 0, cfg.vocab)

    # teacher-forced reference: full forward over the whole sequence
    full_logits, _ = model.forward(params, {"tokens": tokens})

    # serve path: prefill the prompt, then decode with the true next tokens
    logits, cache, pos = model.prefill(
        params, {"tokens": tokens[:, :S_prompt]},
        cache_len=S_prompt + n_new)
    steps = [logits[:, -1]]                       # logits at prompt end
    for t in range(n_new - 1):
        tok = tokens[:, S_prompt + t][:, None]
        logits, cache = model.decode_step(params, tok, cache, pos)
        pos = pos + 1
        steps.append(logits[:, -1])
    got = jnp.stack(steps, axis=1)                # [B, n_new, V]
    want = full_logits[:, S_prompt - 1:S_prompt - 1 + n_new]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_greedy_generation_deterministic():
    cfg = get_smoke_config("gemma-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=2, max_len=64))
    reqs = [Request(rid=i, prompt=[1, 2, 3, 4], max_new_tokens=6)
            for i in range(2)]
    a = eng.serve(reqs)
    b = eng.serve(reqs)
    for i in range(2):
        assert a[i].tokens == b[i].tokens
        assert len(a[i].tokens) == 6
    # identical prompts in one wave → identical continuations
    assert a[0].tokens == a[1].tokens


def test_wave_batching_left_pad():
    """Ragged prompts in one wave produce per-request outputs."""
    cfg = get_smoke_config("internvl2-2b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=3, max_len=64),
                      frontend_seq=4)
    reqs = [Request(0, [5] * 3, 4), Request(1, [9] * 7, 4), Request(2, [2], 4)]
    out = eng.serve(reqs)
    assert sorted(out) == [0, 1, 2]
    assert all(len(out[i].tokens) == 4 for i in range(3))


def test_encdec_serving_smoke():
    cfg = get_smoke_config("seamless-m4t-large-v2")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=2, max_len=32),
                      frontend_seq=6)
    out = eng.serve([Request(0, [1, 2], 4), Request(1, [3, 4, 5], 4)])
    assert len(out[0].tokens) == 4 and len(out[1].tokens) == 4


def test_ssm_cache_is_constant_size():
    """The long_500k story: SSM decode state is O(1) in sequence length."""
    cfg = get_smoke_config("mamba2-130m")
    model = Model(cfg)
    short = model.make_cache(None, batch_size=2, max_len=128)
    long_ = model.make_cache(None, batch_size=2, max_len=1 << 19)
    sizes = lambda c: [x.shape for x in jax.tree.leaves(c)]
    assert sizes(short) == sizes(long_)


def test_deadline_expired_request_is_shed():
    """A request whose deadline_ms has already passed when its wave forms
    is answered with a timed-out Result (no tokens) and never occupies a
    batch slot; undeadlined requests in the same submission are unaffected."""
    cfg = get_smoke_config("gemma-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=2, max_len=64))
    reqs = [Request(0, [1, 2, 3, 4], 6),
            Request(1, [1, 2, 3, 4], 6, deadline_ms=0.0),   # expired on entry
            Request(2, [1, 2, 3, 4], 6, deadline_ms=60_000.0)]
    out = eng.serve(reqs)
    assert sorted(out) == [0, 1, 2]
    assert out[1].timed_out and out[1].tokens == []
    assert not out[0].timed_out and len(out[0].tokens) == 6
    assert not out[2].timed_out and len(out[2].tokens) == 6
    # shedding preserves the answer: same prompt without a deadline
    assert out[2].tokens == out[0].tokens


def test_deadline_mid_batch_shed_later_wave():
    """Deadlines are re-checked at every wave boundary: a tight-deadline
    request queued behind a full first wave is shed when its turn comes."""
    cfg = get_smoke_config("gemma-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=2, max_len=64))
    reqs = [Request(0, [1, 2, 3], 6), Request(1, [1, 2, 3], 6),
            Request(2, [1, 2, 3], 6, deadline_ms=1e-3)]   # behind wave 1
    out = eng.serve(reqs)
    assert out[2].timed_out and out[2].tokens == []
    assert len(out[0].tokens) == 6 and len(out[1].tokens) == 6
