"""Serving correctness: the KV/SSM-cache decode path must reproduce the
full-sequence forward pass (teacher-forced), per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.model import Model
from repro.serve import Request, ServeConfig, ServeEngine


@pytest.mark.parametrize("arch", ["qwen2-72b", "mamba2-130m", "zamba2-2.7b",
                                  "gemma3-4b"])
def test_decode_matches_forward(arch):
    """prefill(prompt) + decode(t) logits == forward(prompt+t) logits."""
    cfg = get_smoke_config(arch).replace(remat="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    B, S_prompt, n_new = 2, 12, 4
    tokens = jax.random.randint(rng, (B, S_prompt + n_new), 0, cfg.vocab)

    # teacher-forced reference: full forward over the whole sequence
    full_logits, _ = model.forward(params, {"tokens": tokens})

    # serve path: prefill the prompt, then decode with the true next tokens
    logits, cache, pos = model.prefill(
        params, {"tokens": tokens[:, :S_prompt]},
        cache_len=S_prompt + n_new)
    steps = [logits[:, -1]]                       # logits at prompt end
    for t in range(n_new - 1):
        tok = tokens[:, S_prompt + t][:, None]
        logits, cache = model.decode_step(params, tok, cache, pos)
        pos = pos + 1
        steps.append(logits[:, -1])
    got = jnp.stack(steps, axis=1)                # [B, n_new, V]
    want = full_logits[:, S_prompt - 1:S_prompt - 1 + n_new]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_greedy_generation_deterministic():
    cfg = get_smoke_config("gemma-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=2, max_len=64))
    reqs = [Request(rid=i, prompt=[1, 2, 3, 4], max_new_tokens=6)
            for i in range(2)]
    a = eng.serve(reqs)
    b = eng.serve(reqs)
    for i in range(2):
        assert a[i].tokens == b[i].tokens
        assert len(a[i].tokens) == 6
    # identical prompts in one wave → identical continuations
    assert a[0].tokens == a[1].tokens


def test_wave_batching_left_pad():
    """Ragged prompts in one wave produce per-request outputs."""
    cfg = get_smoke_config("internvl2-2b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=3, max_len=64),
                      frontend_seq=4)
    reqs = [Request(0, [5] * 3, 4), Request(1, [9] * 7, 4), Request(2, [2], 4)]
    out = eng.serve(reqs)
    assert sorted(out) == [0, 1, 2]
    assert all(len(out[i].tokens) == 4 for i in range(3))


def test_encdec_serving_smoke():
    cfg = get_smoke_config("seamless-m4t-large-v2")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=2, max_len=32),
                      frontend_seq=6)
    out = eng.serve([Request(0, [1, 2], 4), Request(1, [3, 4, 5], 4)])
    assert len(out[0].tokens) == 4 and len(out[1].tokens) == 4


def test_ssm_cache_is_constant_size():
    """The long_500k story: SSM decode state is O(1) in sequence length."""
    cfg = get_smoke_config("mamba2-130m")
    model = Model(cfg)
    short = model.make_cache(None, batch_size=2, max_len=128)
    long_ = model.make_cache(None, batch_size=2, max_len=1 << 19)
    sizes = lambda c: [x.shape for x in jax.tree.leaves(c)]
    assert sizes(short) == sizes(long_)


def test_deadline_expired_request_is_shed():
    """A request whose deadline_ms has already passed when its wave forms
    is answered with a timed-out Result (no tokens) and never occupies a
    batch slot; undeadlined requests in the same submission are unaffected."""
    cfg = get_smoke_config("gemma-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=2, max_len=64))
    reqs = [Request(0, [1, 2, 3, 4], 6),
            Request(1, [1, 2, 3, 4], 6, deadline_ms=0.0),   # expired on entry
            Request(2, [1, 2, 3, 4], 6, deadline_ms=60_000.0)]
    out = eng.serve(reqs)
    assert sorted(out) == [0, 1, 2]
    assert out[1].timed_out and out[1].tokens == []
    assert not out[0].timed_out and len(out[0].tokens) == 6
    assert not out[2].timed_out and len(out[2].tokens) == 6
    # shedding preserves the answer: same prompt without a deadline
    assert out[2].tokens == out[0].tokens


def test_deadline_mid_batch_shed_later_wave():
    """Deadlines are re-checked at every wave boundary: a tight-deadline
    request queued behind a full first wave is shed when its turn comes."""
    cfg = get_smoke_config("gemma-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(batch=2, max_len=64))
    reqs = [Request(0, [1, 2, 3], 6), Request(1, [1, 2, 3], 6),
            Request(2, [1, 2, 3], 6, deadline_ms=1e-3)]   # behind wave 1
    out = eng.serve(reqs)
    assert out[2].timed_out and out[2].tokens == []
    assert len(out[0].tokens) == 6 and len(out[1].tokens) == 6


# ---------------------------------------------------------------------------
# continuous batching on the TaskGraph IR (PR 8)
# ---------------------------------------------------------------------------
def _mk(arch, seed=0, remat="none"):
    cfg = get_smoke_config(arch).replace(remat=remat)
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def _ragged(model, n, seed=7, lo=3, hi=12, budget=None):
    rng = np.random.default_rng(seed)
    V = model.cfg.vocab
    return [Request(i, [int(t) for t in rng.integers(1, V, rng.integers(lo, hi))],
                    max_new_tokens=budget or int(rng.integers(3, 9)))
            for i in range(n)]


def _reference(model, params, reqs, frontend_seq=0, eos=-1):
    """Per-request unpadded B=1 waves: the exact greedy answer."""
    eng = ServeEngine(model, params,
                      ServeConfig(batch=1, max_len=64, eos=eos, mode="wave"),
                      frontend_seq=frontend_seq)
    return eng.serve(reqs)


@pytest.mark.parametrize("arch,fs", [("gemma-7b", 0), ("internvl2-2b", 4)])
def test_padded_wave_matches_unpadded_reference(arch, fs):
    """Satellite fix for the seed's left-padding limitation: ragged waves on
    attention families carry a per-sequence start-index mask, so a padded
    row's greedy tokens are bit-identical to its unpadded reference."""
    model, params = _mk(arch)
    reqs = _ragged(model, 3, budget=5)
    eng = ServeEngine(model, params,
                      ServeConfig(batch=3, max_len=64, mode="wave"),
                      frontend_seq=fs)
    out = eng.serve(reqs)
    ref = _reference(model, params, reqs, frontend_seq=fs)
    for r in reqs:
        assert out[r.rid].tokens == ref[r.rid].tokens


def test_continuous_bit_identical_to_wave():
    """Tentpole acceptance: the continuous batcher's greedy tokens are
    bit-identical to the fixed-wave engine's on fixed seeds."""
    model, params = _mk("gemma-7b")
    reqs = _ragged(model, 7)
    wave = ServeEngine(model, params,
                       ServeConfig(batch=3, max_len=64, mode="wave"))
    cont = ServeEngine(model, params, ServeConfig(batch=3, max_len=64))
    out_w, out_c = wave.serve(reqs), cont.serve(reqs)
    for r in reqs:
        assert out_c[r.rid].tokens == out_w[r.rid].tokens


@pytest.mark.parametrize("arch", ["mamba2-130m", "zamba2-2.7b"])
def test_continuous_exact_prefill_state_families(arch):
    """SSM/hybrid families cannot mask pads; the continuous batcher prefills
    them unpadded (exact length), matching per-request references."""
    model, params = _mk(arch)
    reqs = _ragged(model, 4, budget=4)
    cont = ServeEngine(model, params, ServeConfig(batch=2, max_len=64))
    out = cont.serve(reqs)
    ref = _reference(model, params, reqs)
    for r in reqs:
        assert out[r.rid].tokens == ref[r.rid].tokens


def test_continuous_admission_under_full_batch():
    """More requests than slots: arrivals queue and are admitted as slots
    free, each still decoding its exact greedy continuation."""
    model, params = _mk("gemma-7b")
    reqs = _ragged(model, 6, budget=None)
    eng = ServeEngine(model, params, ServeConfig(batch=2, max_len=64))
    out = eng.serve(reqs)
    assert sorted(out) == [r.rid for r in reqs]
    ref = _reference(model, params, reqs)
    for r in reqs:
        assert not out[r.rid].timed_out
        assert out[r.rid].tokens == ref[r.rid].tokens


def test_midstream_eos_frees_slot():
    """A sequence hitting EOS mid-stream retires at the step boundary and
    its slot is re-used by the next queued request, while the surviving
    batchmate keeps decoding bit-exactly."""
    model, params = _mk("gemma-7b")
    probe = [Request(0, [3, 1, 4, 1, 5], 6)]
    first = _reference(model, params, probe)[0].tokens
    eos = first[1]                        # r0 will stop after two tokens
    reqs = [Request(0, [3, 1, 4, 1, 5], 6),
            Request(1, [2, 7, 1, 8], 6),
            Request(2, [9, 2, 6], 6)]     # queued behind a full batch
    eng = ServeEngine(model, params,
                      ServeConfig(batch=2, max_len=64, eos=eos))
    out = eng.serve(reqs)
    assert out[0].tokens[-1] == eos and len(out[0].tokens) < 6
    ref = _reference(model, params, reqs, eos=eos)
    for r in reqs:
        assert out[r.rid].tokens == ref[r.rid].tokens


def test_deadline_shed_from_admission_queue():
    """Continuous admission re-checks deadlines whenever a slot frees: a
    tight-deadline request queued behind a busy slot is shed, never
    admitted, and the slot-holder is unaffected."""
    model, params = _mk("gemma-7b")
    eng = ServeEngine(model, params, ServeConfig(batch=1, max_len=64))
    reqs = [Request(0, [1, 2, 3], 8),
            Request(1, [4, 5, 6], 8, deadline_ms=1e-3)]
    out = eng.serve(reqs)
    assert out[1].timed_out and out[1].tokens == []
    assert not out[0].timed_out and len(out[0].tokens) == 8


# ---------------------------------------------------------------------------
# pool mode: device-resident caches, placement, migration, spilling
# ---------------------------------------------------------------------------
def _cluster(n, capacity=None):
    from repro.core import ClusterRuntime, RuntimeConfig
    return ClusterRuntime(RuntimeConfig(
        n_virtual=n, device_capacity_bytes=capacity))


def test_pool_serving_matches_local():
    """Pool mode lowers the same loop onto per-sequence TaskNodes with
    device-resident caches; greedy tokens stay bit-identical, under both
    placement policies."""
    model, params = _mk("gemma-7b")
    reqs = _ragged(model, 5)
    local = ServeEngine(model, params, ServeConfig(batch=3, max_len=64))
    out_l = local.serve(reqs)
    rt = _cluster(2)
    try:
        for policy in ("slo", "round-robin"):
            eng = ServeEngine(model, params,
                              ServeConfig(batch=3, max_len=64),
                              runtime=rt, policy=policy)
            out_p = eng.serve(reqs)
            for r in reqs:
                assert out_p[r.rid].tokens == out_l[r.rid].tokens
    finally:
        rt.shutdown()


def test_pool_migration_rebalances_tail():
    """Round-robin parks two long sequences on device 0; once the short
    ones retire, the queue-depth gap triggers a cache migration (via
    propagate_resident) and tokens stay bit-identical."""
    model, params = _mk("gemma-7b")
    reqs = [Request(0, [1, 2, 3], 12), Request(1, [4, 5], 2),
            Request(2, [6, 7, 8], 12), Request(3, [9, 1], 2)]
    local = ServeEngine(model, params, ServeConfig(batch=4, max_len=64))
    out_l = local.serve(reqs)
    rt = _cluster(2)
    try:
        eng = ServeEngine(model, params,
                          ServeConfig(batch=4, max_len=64, migrate_every=1),
                          runtime=rt, policy="round-robin")
        out_p = eng.serve(reqs)
        assert eng.migrations >= 1
        for r in reqs:
            assert out_p[r.rid].tokens == out_l[r.rid].tokens
    finally:
        rt.shutdown()


def test_capacity_lru_spill_refetch_bit_identical():
    """With device capacity below the working set, cold sequence caches
    spill to the host and transparently refetch on their next decode step;
    tokens are bit-identical to the uncapped run."""
    import jax.numpy as jnp
    model, params = _mk("gemma-7b")
    reqs = _ragged(model, 6, budget=6)
    rt = _cluster(2)
    try:
        ref_eng = ServeEngine(model, params,
                              ServeConfig(batch=4, max_len=64), runtime=rt)
        out_u = ref_eng.serve(reqs)
        tpl = ref_eng._ctpl
    finally:
        rt.shutdown()
    cache_b = sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                  for s in jax.tree.leaves(tpl))
    param_b = sum(l.size * l.dtype.itemsize
                  for l in jax.tree.leaves(params))
    rt2 = _cluster(2, capacity=param_b + int(1.5 * cache_b))
    try:
        eng = ServeEngine(model, params,
                          ServeConfig(batch=4, max_len=64), runtime=rt2)
        out_c = eng.serve(reqs)
        stats = [rt2.pool.present[d].stats() for d in range(2)]
        assert sum(s["evictions"] for s in stats) > 0
        assert sum(s["refetches"] for s in stats) > 0
        for r in reqs:
            assert out_c[r.rid].tokens == out_u[r.rid].tokens
    finally:
        rt2.shutdown()


def test_pool_deadline_shed_from_queue():
    """deadline_ms keeps working under the TaskGraph executor: an expired
    queued request is shed before placement ever allocates it a cache."""
    model, params = _mk("gemma-7b")
    rt = _cluster(2)
    try:
        eng = ServeEngine(model, params, ServeConfig(batch=1, max_len=64),
                          runtime=rt)
        reqs = [Request(0, [1, 2, 3], 6),
                Request(1, [4, 5, 6], 6, deadline_ms=1e-3)]
        out = eng.serve(reqs)
        assert out[1].timed_out and out[1].tokens == []
        assert len(out[0].tokens) == 6
        # the shed request never became resident anywhere
        for d in range(2):
            assert rt.pool.present[d].get("_serve_c1") is None
    finally:
        rt.shutdown()
