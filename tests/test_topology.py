"""Hierarchical topology (PR 9 tentpole): rack-aware collectives that are
bit-identical to the flat/serial association while moving O(R) instead of
O(D) cross-rack traffic, per-pair edge pricing, compression-aware edge
routing, and the funnel-fallback ladder under a dead rack-leader link."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container image lacks hypothesis
    from _hypothesis_shim import given, settings, st

import jax
import jax.numpy as jnp
import pytest

from repro.core import (ClusterRuntime, CostModel, DagTask, DevicePool,
                        HeftPlacement, INTRA_RACK, KernelTable, LinkModel,
                        MapSpec, PAPER_ETHERNET, PeerTransport,
                        PlacementContext, PlacementPolicy, RuntimeConfig,
                        Topology)
from repro.ft import inject_flaky


def _pool(n):
    table = KernelTable()
    table.register("combine", lambda x: {"out": x @ x * 1e-2 + 1.0})
    table.register("combine2", lambda x, y: {"out": x @ x * 1e-2 + y})
    return DevicePool.virtual(n, table=table)


def _install(pool, d, value):
    value = jnp.asarray(value)
    h = pool.alloc(d, value.shape, value.dtype)
    pool.transfer_to(d, h, value)
    return h


def _leaf_values(D, L=2, seed=0, shape=(5, 3)):
    rng = np.random.default_rng(seed)
    return [[jnp.asarray(rng.standard_normal(shape), jnp.float32)
             for _ in range(L)] for _ in range(D)]


def _setup_collective(D, values):
    pool = _pool(D)
    handles = [[_install(pool, d, v) for v in values[d]] for d in range(D)]
    specs = [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in values[0]]
    return pool, handles, specs


# ---------------------------------------------------------------------------
# the Topology object itself
# ---------------------------------------------------------------------------
def test_constructor_rejects_non_contiguous_racks():
    Topology([[0, 1], [2, 3]])                      # fine
    Topology([[0], [1, 2], [3]])                    # uneven is fine too
    for bad in ([[0, 1], [3, 4]],                   # gap
                [[1, 0], [2, 3]],                   # not ascending in-rack
                [[2, 3], [0, 1]],                   # racks out of order
                [[0, 1], []],                       # empty rack
                []):                                # no racks at all
        with pytest.raises(ValueError):
            Topology(bad)


def test_shape_constructors():
    t = Topology.two_tier(2, 4)
    assert t.racks == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert (t.n_devices, t.n_racks) == (8, 2)
    p = Topology.partition(7, 3)
    assert p.racks == ((0, 1, 2), (3, 4, 5), (6,))  # remainder rack
    with pytest.raises(ValueError, match="per_rack"):
        Topology.partition(4, 0)
    f = Topology.flat(4)
    assert f.n_racks == 1 and f.intra is f.inter is PAPER_ETHERNET


def test_structure_queries():
    t = Topology.two_tier(2, 3)
    assert t.rack_of(0) == 0 and t.rack_of(5) == 1
    assert t.same_rack(0, 2) and not t.same_rack(2, 3)
    assert t.cross_rack(1, 4) and not t.cross_rack(4, 5)
    assert t.members(1) == (3, 4, 5)
    assert t.leaders() == [0, 3]
    assert t.leader_of(5) == 3 and t.leader(0) == 0
    assert t.covers(0, 5) and not t.covers(0, 6)


def test_link_between_and_overrides():
    t = Topology.two_tier(2, 2, inter_bw_ratio=0.1)
    assert t.link_between(0, 1) is t.intra
    assert t.link_between(1, 2) is t.inter
    assert t.inter.bandwidth_Bps == pytest.approx(t.intra.bandwidth_Bps * 0.1)
    # the default two-tier spine at ratio 0.1 IS the paper's Gbit Ethernet
    assert t.inter.bandwidth_Bps == pytest.approx(PAPER_ETHERNET.bandwidth_Bps)
    degraded = LinkModel("degraded", 1e6, 1e-3)
    t.set_link(0, 3, degraded)
    assert t.link_between(0, 3) is degraded
    assert t.link_between(3, 0) is degraded         # undirected by default
    t.set_link(1, 2, degraded, directed=True)
    assert t.link_between(1, 2) is degraded
    assert t.link_between(2, 1) is t.inter
    assert t.pair_time(0, 3, 1000) == pytest.approx(degraded.time(1000, 1))


def test_compression_decision_is_per_link():
    t = Topology.two_tier(2, 4, inter_bw_ratio=0.1)
    big = 1 << 20
    # fat intra-rack link: savings never beat the quantize cost
    assert not t.compression_wins(0, 1, big)
    # thin spine, big message: int8 wire wins and is strictly faster
    sec, comp = t.edge_seconds(0, 4, big)
    assert comp and sec < t.inter.time(big, 1)
    # tiny message: per-block scales make the wire LARGER -> never compress
    assert t.int8_wire_nbytes(16) > 16
    assert not t.compression_wins(0, 4, 16)
    # wire-size arithmetic: 300 f32 elements = 2 blocks of 256 + 2 scales
    assert t.int8_wire_nbytes(1200) == 2 * 256 + 2 * 4
    d = t.describe()
    assert d["racks"] == [[0, 1, 2, 3], [4, 5, 6, 7]]
    assert d["inter"]["bandwidth_Bps"] == pytest.approx(1.25e8)


# ---------------------------------------------------------------------------
# hierarchical collectives: fewer cross-rack bytes, identical bits
# ---------------------------------------------------------------------------
def test_hier_allreduce_moves_fewer_cross_rack_bytes():
    """2 racks x 4 devices: the flat ring crosses the spine 2(D-1) times,
    the hierarchical chain 2(R-1) times — an 85% cut, well past the 40%
    acceptance floor."""
    topo = Topology.two_tier(2, 4, inter_bw_ratio=0.1)
    n = 300
    values = [[jnp.full((n,), float(d + 1), jnp.float32)] for d in range(8)]

    def run(transport):
        pool, handles, specs = _setup_collective(8, values)
        pool.cost.topology = topo            # cross-rack accounting
        transport.ring_allreduce(pool, handles, specs)
        pool.sync()
        got = np.asarray(pool.transfer_from(0, handles[0][0]))
        cross = pool.cost.bytes_peer_cross_rack()
        assert pool.cost.summary()["bytes_peer_cross_rack"] == cross
        pool.stop_all()
        return got, cross

    flat_v, flat_x = run(PeerTransport())
    hier_v, hier_x = run(PeerTransport(topology=topo))
    buf = n * 4
    assert flat_x == 2 * 7 * buf                 # (D-1) crossings per link, x2
    assert hier_x == 2 * 1 * buf                 # leader chain + broadcast
    assert hier_x <= 0.6 * flat_x
    np.testing.assert_allclose(hier_v, flat_v, rtol=1e-6)


def test_hier_ring_allreduce_sums_bitwise_and_frees_scratch():
    topo = Topology.two_tier(2, 3)
    D = 6
    values = _leaf_values(D, seed=5)
    pool, handles, specs = _setup_collective(D, values)
    PeerTransport(topology=topo).ring_allreduce(pool, handles, specs)
    # the hierarchical sum carries the SERIAL ascending association
    want = [np.asarray(sum((values[d][j] for d in range(1, D)),
                           values[0][j])) for j in range(2)]
    pool.sync()
    for d in range(D):
        for j in range(2):
            got = np.asarray(pool.transfer_from(d, handles[d][j]))
            np.testing.assert_array_equal(got, want[j]), (d, j)
        assert len(pool.devices[d].store.live_handles()) == 2, d
    pool.stop_all()


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 7), st.integers(1, 4), st.integers(0, 10_000))
def test_hier_mean_bit_identical_to_flat_and_serial(D, per_rack, seed):
    """Property: for ANY rack shape (odd D, remainder racks, singleton
    racks) the hierarchical mean equals the flat allreduce_mean equals the
    host-serial ``sum(views)/D`` — bitwise, on every device."""
    topo = Topology.partition(D, per_rack)
    values = _leaf_values(D, L=2, seed=seed, shape=(3, 2))
    serial = [np.asarray(sum(v[j] for v in values) / D) for j in range(2)]

    def run(transport):
        pool, handles, specs = _setup_collective(D, values)
        transport.allreduce_mean(pool, handles, specs)
        pool.sync()
        out = [[np.asarray(pool.transfer_from(d, handles[d][j]))
                for j in range(2)] for d in range(D)]
        for dev in pool.devices:             # no leaked collective scratch
            assert len(dev.store.live_handles()) == 2
        pool.stop_all()
        return out

    hier = run(PeerTransport(topology=topo))
    flat = run(PeerTransport())
    for d in range(D):
        for j in range(2):
            np.testing.assert_array_equal(hier[d][j], serial[j]), (d, j)
            np.testing.assert_array_equal(flat[d][j], serial[j]), (d, j)


def test_hier_broadcast_delivers_root_value_everywhere():
    topo = Topology.partition(5, 2)          # (0,1) (2,3) (4,)
    values = _leaf_values(5, seed=9)
    pool, handles, specs = _setup_collective(5, values)
    PeerTransport(topology=topo).broadcast(pool, handles, specs, root=3)
    pool.sync()
    for d in range(5):
        for j in range(2):
            np.testing.assert_array_equal(
                np.asarray(pool.transfer_from(d, handles[d][j])),
                np.asarray(values[3][j]))
    pool.stop_all()


def test_single_rack_topology_keeps_flat_collectives():
    """One rack never dispatches the hierarchical path (n_racks > 1 guard):
    flat topology is pricing-only."""
    tr = PeerTransport(topology=Topology.flat(3))
    assert not tr._hier_ok(3)
    assert PeerTransport(topology=Topology.two_tier(2, 2))._hier_ok(4)
    # size mismatch (subset pool) also falls back to the flat path
    assert not PeerTransport(topology=Topology.two_tier(2, 2))._hier_ok(3)


# ---------------------------------------------------------------------------
# chaos: a dead rack-leader link degrades through the fallback ladder
# ---------------------------------------------------------------------------
def test_hier_mean_survives_dead_rack_leader_link():
    """Every SEND/RECV on rack 1's leader fails hard: retries exhaust,
    the funnel fallback carries the leader's messages through the host —
    and the delivered bits are still the serial association."""
    topo = Topology.two_tier(2, 2)
    D = 4
    values = _leaf_values(D, seed=13)
    serial = [np.asarray(sum(v[j] for v in values) / D) for j in range(2)]
    pool, handles, specs = _setup_collective(D, values)
    leader = topo.leader(1)
    inject_flaky(pool, p=1.0, seed=1, devices=[leader],
                 ops=("SEND", "RECV"))
    tr = PeerTransport(retries=1, backoff_base_s=1e-5, topology=topo)
    tr.allreduce_mean(pool, handles, specs)
    pool.sync()
    assert tr.fallbacks > 0                  # the ladder actually engaged
    for d in range(D):
        for j in range(2):
            np.testing.assert_array_equal(
                np.asarray(pool.transfer_from(d, handles[d][j])),
                serial[j]), (d, j)
    pool.stop_all()


# ---------------------------------------------------------------------------
# per-pair edge pricing + compression-aware routing at the placement layer
# ---------------------------------------------------------------------------
def test_peer_edge_time_is_per_pair_under_topology():
    topo = Topology.two_tier(2, 2, inter_bw_ratio=0.1)
    tr = PeerTransport(topology=topo)
    cost = CostModel(PAPER_ETHERNET)
    n = 1 << 16
    intra = tr.edge_time(cost, 0, 1, n)
    inter = tr.edge_time(cost, 0, 2, n)
    assert intra < inter
    assert intra == pytest.approx(topo.intra.time(n, 1))
    # cross-rack price folds in the compression decision (int8 wire beats
    # raw on the spine at this size), so it undercuts the raw spine time
    assert inter == pytest.approx(topo.edge_seconds(0, 2, n)[0])
    assert inter < topo.inter.time(n, 1)
    # a pair the topology does not cover falls back to the flat peer link
    assert tr.edge_time(cost, 0, 7, n) == pytest.approx(
        PAPER_ETHERNET.time(n, 1))


def test_route_edge_compresses_only_where_the_link_is_thin():
    topo = Topology.two_tier(2, 2, inter_bw_ratio=0.1)
    tr = PeerTransport(topology=topo)
    cost = CostModel(PAPER_ETHERNET)
    ctx = PlacementContext(pool=None, cost=cost, D=4, peer=True,
                           transport=tr, topology=topo)
    policy = PlacementPolicy()
    big, tiny = 1 << 16, 16
    assert policy.route_edge(ctx, 0, 1, big) == "peer"        # fat intra
    assert policy.route_edge(ctx, 0, 2, big) == "peer+int8"   # thin spine
    assert policy.route_edge(ctx, 0, 2, tiny) == "peer"       # scale overhead
    heft = HeftPlacement(default_task_s=5e-6, use_observed=False)
    assert heft.route_edge(ctx, 0, 2, big) == "peer+int8"


def test_heft_packs_hot_edges_intra_rack():
    """Two consumers of one big producer output on a 2x2 topology with a
    punishing spine: EFT parks the second consumer on the producer's rack
    peer, never across the spine."""
    topo = Topology.two_tier(2, 2, inter_bw_ratio=0.01)
    tr = PeerTransport(topology=topo)
    cost = CostModel(PAPER_ETHERNET)
    nbytes = 1 << 20
    ctx = PlacementContext(pool=None, cost=cost, D=4, peer=True,
                           transport=tr, topology=topo,
                           home={"prod": 1}, out_bytes={"prod": nbytes})
    heft = HeftPlacement(default_task_s=5e-3, use_observed=False)
    heft.begin(ctx)
    from repro.core import TaskNode
    placed = [heft.place(ctx, TaskNode(f"c{i}", "combine", ("prod",), None),
                         i, "t") for i in range(2)]
    assert placed[0] == 1                    # free edge: producer's device
    assert placed[1] == 0                    # rack peer, NOT 2/3 over spine
    assert set(placed) <= set(topo.members(0))


# ---------------------------------------------------------------------------
# runtime integration: modeled wire compression keeps results bit-identical
# ---------------------------------------------------------------------------
def _chain_tasks(B=64, length=4, seed=0):
    """A pinned chain that zig-zags the 2x2 rack boundary: p0@0 -> p1@1
    (intra) -> p2@2 (spine) -> p3@3 (intra) -> p4@0 (spine)."""
    rng = np.random.default_rng(seed)
    init = jnp.asarray(rng.standard_normal((B, B)), jnp.float32)
    sds = jax.ShapeDtypeStruct((B, B), jnp.float32)
    tasks = [DagTask("p0", "combine", (),
                     lambda dv: MapSpec(to={"x": init}, from_={"out": sds}),
                     device=0)]
    for w in range(1, length + 1):
        tasks.append(DagTask(
            f"p{w}", "combine2", (f"p{w-1}", "p0"),
            (lambda w=w: lambda dv: MapSpec(
                to={"x": dv[f"p{w-1}"], "y": dv["p0"]},
                from_={"out": sds}))(),
            device=w % 4))
    return tasks


def _run_chain(topology):
    table = KernelTable()
    table.register("combine", lambda x: {"out": x @ x * 1e-2 + 1.0})
    table.register("combine2", lambda x, y: {"out": x @ x * 1e-2 + y})
    rt = ClusterRuntime(RuntimeConfig(n_virtual=4, topology=topology),
                        table=table)
    try:
        res = rt.wavefront_offload(_chain_tasks(), nowait=True, peer=True,
                                   policy="round-robin")
        return {k: np.asarray(v) for k, v in res.items()}, rt.cost.summary()
    finally:
        rt.shutdown()


def test_compressed_edge_routing_is_bit_identical_and_accounted():
    """Round-robin drives the chain across the spine; edges big enough for
    the int8 wire route as "peer+int8" — modeled bytes shrink, cross-rack
    traffic is itemized, and the VALUES are bitwise those of the raw run
    (wire compression is accounting-only on dependency edges)."""
    topo = Topology.two_tier(2, 2, inter_bw_ratio=0.1)
    raw_vals, raw_stats = _run_chain(None)
    top_vals, top_stats = _run_chain(topo)
    assert raw_vals.keys() == top_vals.keys()
    for k in raw_vals:
        np.testing.assert_array_equal(raw_vals[k], top_vals[k]), k
    assert raw_stats["bytes_peer_cross_rack"] == 0       # no topology: n/a
    assert top_stats["bytes_peer_cross_rack"] > 0
    # the compressed wire moved fewer modeled peer bytes than raw routing
    assert top_stats["bytes_peer"] < raw_stats["bytes_peer"]


def test_runtime_rejects_topology_size_mismatch():
    with pytest.raises(ValueError, match="topology"):
        ClusterRuntime(RuntimeConfig(n_virtual=3,
                                     topology=Topology.two_tier(2, 2)),
                       table=KernelTable())
