"""Sharding rules engine: divisibility fallback, axis-claim ordering,
param-name coverage over real models, HLO collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container image lacks hypothesis
    from _hypothesis_shim import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS, get_smoke_config
from repro.launch.hlo_analysis import (CollectiveStats, collective_stats,
                                       model_flops_for)
from repro.models.model import Model
from repro.parallel.sharding import AxisRules, spec_for
from repro.train.specs import cache_names, param_names
from repro.train.steps import default_rules, rules_variant


def _mesh(shape=(2, 4), axes=("data", "model")):
    return jax.make_mesh(shape, axes)     # host devices: works abstractly


@pytest.fixture(scope="module")
def mesh():
    # a 1-device mesh with logical 2D shape is impossible; use shape math
    # only — spec_for never touches devices, so fabricate via numpy reshape
    import numpy as _np
    devs = _np.asarray(jax.devices()[:1] * 8).reshape(2, 4) \
        if len(jax.devices()) == 1 else None
    if devs is not None:
        class FakeMesh:
            shape = {"data": 2, "model": 4}
            axis_names = ("data", "model")
        return FakeMesh()
    return _mesh()


def test_spec_divisibility_fallback(mesh):
    rules = AxisRules.of(batch="data", ff="model")
    # ff=10 not divisible by model=4 → replicated; batch=6 divisible by 2
    s = spec_for((6, 10), ("batch", "ff"), rules, mesh)
    assert s == P("data")
    s2 = spec_for((6, 16), ("batch", "ff"), rules, mesh)
    assert s2 == P("data", "model")


def test_spec_first_claim_wins(mesh):
    rules = AxisRules.of(a="model", b="model")
    s = spec_for((8, 8), ("a", "b"), rules, mesh)
    assert s == P("model")                  # b falls back, later dims trimmed


def test_spec_tuple_axes(mesh):
    rules = AxisRules.of(batch=("data", "model"))
    assert spec_for((8, 4), ("batch", None), rules, mesh) == P(("data", "model"))
    # 6 % (2*4) != 0 → replicate
    assert spec_for((6, 4), ("batch", None), rules, mesh) == P()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=1, max_size=4),
       st.sampled_from(["batch", "ff", "heads", None]))
def test_spec_never_over_shards(mesh, dims, name):
    """Property: every sharded dim is divisible by its mesh axes product."""
    rules = default_rules()
    names = [name] * len(dims)
    s = spec_for(tuple(dims), names, rules, mesh)
    sizes = {"data": 2, "model": 4}
    for dim, part in zip(dims, tuple(s) + (None,) * (len(dims) - len(s))):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else part
        prod = int(np.prod([sizes[a] for a in axes]))
        assert dim % prod == 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_names_cover_every_leaf(arch):
    """Every parameter leaf receives a name tuple of exactly its rank."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    abstract = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    names = param_names(abstract)
    flat_p = jax.tree.leaves(abstract)
    flat_n = jax.tree.leaves(names, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_p) == len(flat_n)
    for leaf, nm in zip(flat_p, flat_n):
        assert len(nm) == len(leaf.shape), (nm, leaf.shape)


def test_rules_variants_exist():
    for v in ("default", "dp-only", "tp-heavy", "seq-model", "kv-model",
              "zero-all"):
        rules_variant(v)
    with pytest.raises(KeyError):
        rules_variant("nope")


# ---------------------------------------------------------------------------
# HLO collective parsing (the §Roofline data source)
# ---------------------------------------------------------------------------
HLO_SAMPLES = """
  %all-reduce.150 = f32[32,4096]{1,0} all-reduce(%x), replica_groups=[8,8]<=[64]
  %all-gather.69 = bf16[768]{0} all-gather(%y), replica_groups=[4,16]<=[64]
  %all-gather-start.1 = (f32[768]{0}, f32[6144]{0}) all-gather-start(%z), replica_groups=[8,8]<=[64]
  %all-gather-done.1 = f32[6144]{0} all-gather-done(%all-gather-start.1)
  %reduce-scatter.5 = f32[96]{0} reduce-scatter(%g), replica_groups={{0,1,2,3,4,5,6,7}}
  %collective-permute.3 = s32[16]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %fusion.1 = f32[8]{0} fusion(%a), kind=kLoop
"""


def test_collective_stats_parsing():
    st_ = collective_stats(HLO_SAMPLES)
    assert st_.by_kind["all-reduce"] == 32 * 4096 * 4
    assert st_.by_kind["all-gather"] == 768 * 2 // 16 + 6144 * 4 // 8
    assert st_.by_kind["reduce-scatter"] == 96 * 4 * 8
    assert st_.by_kind["collective-permute"] == 16 * 4
    assert st_.by_kind_count["all-gather"] == 2       # done not double-counted
    assert st_.total_ops == 5
    assert st_.link_bytes > 0


def test_collective_stats_empty():
    st_ = collective_stats("%add = f32[2]{0} add(%a, %b)")
    assert st_.total_bytes == 0 and st_.total_ops == 0


def test_model_flops_formulas():
    n_tot, n_act = 100, 50
    assert model_flops_for(None, "train", 10, 2, n_tot, n_act) == 6 * 50 * 20
    assert model_flops_for(None, "prefill", 10, 2, n_tot, n_act) == 2 * 50 * 20
    assert model_flops_for(None, "decode", 10, 2, n_tot, n_act) == 2 * 50 * 2


def test_auto_policy_selection():
    from repro.configs.registry import get_config
    from repro.train.steps import auto_policy
    assert auto_policy(get_config("qwen2-72b"), "decode", 128, 256) == "kv-model"
    assert auto_policy(get_config("mamba2-130m"), "prefill", 32, 256) == "dp-only"
    assert auto_policy(get_config("kimi-k2-1t-a32b"), "train", 256, 256) == "moe-ep4"
    assert auto_policy(get_config("qwen2-72b"), "train", 256, 256) == "fsdp"
    assert auto_policy(get_config("qwen2-72b"), "prefill", 32, 256) == "zero-all"
