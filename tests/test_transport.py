"""Peer transport layer (PR 4 tentpole): SEND/RECV rendezvous in the
dependency-aware stream, topology-agnostic collectives, per-link peer lanes
in the cost model, and deadlock-freedom / serial-equivalence properties."""
import concurrent.futures as _cf
import threading
import time

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container image lacks hypothesis
    from _hypothesis_shim import given, settings, st

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModel, DevicePool, HostFunnelTransport,
                        KernelTable, LinkModel, PeerTransport)


def _pool(n):
    table = KernelTable()
    table.register("triple", lambda a: {"a": a * 3.0 + 1.0})
    return DevicePool.virtual(n, table=table)


def _install(pool, d, value):
    value = jnp.asarray(value)
    h = pool.alloc(d, value.shape, value.dtype)
    pool.transfer_to(d, h, value)
    return h


# ---------------------------------------------------------------------------
# the primitive: peer_copy / sendrecv
# ---------------------------------------------------------------------------
def test_peer_copy_moves_value_without_funnel_bytes():
    pool = _pool(2)
    v = jnp.arange(16.0, dtype=jnp.float32)
    hs = _install(pool, 0, v)
    hd = pool.alloc(1, v.shape, v.dtype)
    before = (pool.cost.bytes_moved("to"), pool.cost.bytes_moved("from"))
    pool.peer_copy(0, hs, 1, hd)
    got = pool.transfer_from(1, hd)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(v))
    # the copy itself crossed zero host-NIC bytes; it is peer traffic
    after_to = pool.cost.bytes_moved("to")
    assert after_to == before[0]
    assert pool.cost.bytes_peer() == v.size * 4
    # and it is a real pair of stream commands on both devices
    ops0 = [c.op for c in pool.stream_traces[0]]
    ops1 = [c.op for c in pool.stream_traces[1]]
    assert "SEND" in ops0 and "RECV" in ops1
    pool.stop_all()


def test_peer_copy_orders_like_a_stream_writer():
    """RECV is a writer of the destination handle: a consumer EXEC issued
    after the copy must see the received value, and a SEND issued after a
    producer XFER_TO must carry the produced value — even with the issue
    happening while the source worker is stalled."""
    pool = _pool(2)
    v0 = jnp.zeros(8, jnp.float32)
    hs = _install(pool, 0, v0)
    hd = _install(pool, 1, jnp.full(8, -1.0, jnp.float32))
    gate = threading.Event()
    pool._submit(0, gate.wait)               # stall device 0's stream
    pool.transfer_to(0, hs, jnp.full(8, 7.0, jnp.float32))   # producer
    pool.peer_copy(0, hs, 1, hd)                              # SEND after it
    threading.Timer(0.2, gate.set).start()   # release mid-exec-wait
    # exec_kernel blocks until the chain produce -> SEND -> RECV -> EXEC ran
    out = pool.exec_kernel(1, "triple", buffers={"a": hd})
    np.testing.assert_allclose(np.asarray(out["a"]), 7.0 * 3.0 + 1.0)
    pool.stop_all()


def test_peer_copy_recv_failure_surfaces_at_destination_sync():
    pool = _pool(2)
    hs = _install(pool, 0, jnp.ones(4))
    hd = pool.alloc(1, (4,), jnp.float32)
    pool.free(1, hd)                          # RECV will write a dead handle
    pool.peer_copy(0, hs, 1, hd)
    with pytest.raises(KeyError, match="not live"):
        pool.sync(1)
    # the stash is cleared and the source side was unaffected
    pool.sync()
    pool.stop_all()


def test_ring_rendezvous_is_deadlock_free():
    """A full ring of peer copies (0→1→…→D-1→0) issued while EVERY worker is
    stalled, in an adversarial issue order, completes once released: RECV is
    gated on its SEND through the dependency graph, so no worker ever parks
    inside a rendezvous."""
    D = 4
    pool = _pool(D)
    src = [_install(pool, d, jnp.full(8, float(d), jnp.float32))
           for d in range(D)]
    dst = [pool.alloc(d, (8,), jnp.float32) for d in range(D)]
    gates = [threading.Event() for _ in range(D)]
    for d in range(D):
        pool._submit(d, gates[d].wait)
    # adversarial order: issue the ring backwards
    for d in reversed(range(D)):
        pool.peer_copy(d, src[d], (d + 1) % D, dst[(d + 1) % D])
    for g in reversed(gates):
        g.set()
    deadline = time.monotonic() + 20
    for d in range(D):
        got = pool.transfer_from(d, dst[d])
        np.testing.assert_allclose(np.asarray(got), float((d - 1) % D))
        assert time.monotonic() < deadline
    pool.stop_all()


# ---------------------------------------------------------------------------
# collectives: same algorithm over either topology
# ---------------------------------------------------------------------------
def _leaf_values(D, L=2, seed=0):
    rng = np.random.default_rng(seed)
    return [[jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)
             for _ in range(L)] for _ in range(D)]


def _setup_collective(D, values):
    pool = _pool(D)
    handles = [[_install(pool, d, v) for v in values[d]] for d in range(D)]
    specs = [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in values[0]]
    return pool, handles, specs


@pytest.mark.parametrize("transport_cls", [PeerTransport, HostFunnelTransport])
def test_ring_allreduce_sums_on_every_device(transport_cls):
    D = 3
    values = _leaf_values(D)
    pool, handles, specs = _setup_collective(D, values)
    transport_cls().ring_allreduce(pool, handles, specs)
    want = [sum(np.asarray(values[d][j]) for d in range(D)) for j in range(2)]
    for d in range(D):
        for j in range(2):
            got = np.asarray(pool.transfer_from(d, handles[d][j]))
            np.testing.assert_allclose(got, want[j], rtol=1e-5, atol=1e-6)
    # scratch freed: only the 2 leaves per device stay live
    pool.sync()
    for d in range(D):
        assert len(pool.devices[d].store.live_handles()) == 2, d
    pool.stop_all()


def test_ring_allreduce_topologies_account_differently():
    """The SAME ring over the two transports: peer moves its bytes on links,
    the funnel pays every hop twice through the host NIC."""
    D, n = 3, 64
    values = [[jnp.full((n,), float(d + 1), jnp.float32)] for d in range(D)]

    def run(transport):
        pool, handles, specs = _setup_collective(D, values)
        transport.ring_allreduce(pool, handles, specs)
        pool.sync()
        s = pool.cost.summary()
        pool.stop_all()
        return s

    base_pool, _, _ = _setup_collective(D, values)   # setup-only baseline
    base = base_pool.cost.summary()
    base_pool.stop_all()
    peer = run(PeerTransport())
    funnel = run(HostFunnelTransport())
    ring_bytes = D * (D - 1) * n * 4
    assert peer["bytes_peer"] == ring_bytes
    assert peer["bytes_from"] == base["bytes_from"]          # zero extra funnel
    assert funnel["bytes_peer"] == 0
    # every ring message = one fetch + one re-send through the host
    assert funnel["bytes_from"] - base["bytes_from"] == ring_bytes
    assert funnel["bytes_to"] - base["bytes_to"] == ring_bytes


def test_broadcast_and_gather():
    D = 4
    values = _leaf_values(D, seed=3)
    pool, handles, specs = _setup_collective(D, values)
    t = PeerTransport()
    scratch = t.gather(pool, handles, specs, root=2)
    for d, hs in scratch.items():
        for j, h in enumerate(hs):
            np.testing.assert_array_equal(
                np.asarray(pool.transfer_from(2, h)), np.asarray(values[d][j]))
        for h in hs:
            pool.free(2, h)
    t.broadcast(pool, handles, specs, root=2)
    for d in range(D):
        for j in range(2):
            np.testing.assert_array_equal(
                np.asarray(pool.transfer_from(d, handles[d][j])),
                np.asarray(values[2][j]))
    pool.stop_all()


@pytest.mark.parametrize("root", [0, 2])
def test_allreduce_mean_bit_identical_to_host_order(root):
    """The reduction accumulates in ascending DEVICE order — the exact
    association of the host-mediated ``sum(views) / D`` — for any root,
    not just root 0."""
    D = 4
    values = _leaf_values(D, seed=7)
    pool, handles, specs = _setup_collective(D, values)
    PeerTransport().allreduce_mean(pool, handles, specs, root=root)
    want = [np.asarray(sum(v[j] for v in values) / D) for j in range(2)]
    for d in range(D):
        for j in range(2):
            got = np.asarray(pool.transfer_from(d, handles[d][j]))
            np.testing.assert_array_equal(got, want[j])
    pool.sync()
    for d in range(D):                       # gather scratch freed
        assert len(pool.devices[d].store.live_handles()) == 2, d
    pool.stop_all()


@pytest.mark.parametrize("root", [0, 1])
def test_allreduce_mean_failure_leaves_live_buffers_intact(root):
    """A mid-collective failure must not corrupt any device's live buffer:
    partial sums land only in scratch, the root's buffer is written once by
    the final divide — all-or-nothing, like the host-mediated path."""
    from repro.core.transport import DIV_KERNEL

    D = 3
    values = _leaf_values(D, seed=11)
    pool, handles, specs = _setup_collective(D, values)
    # pre-register a failing divide: _ensure_kernels keeps it (same wire
    # name).  The divide runs AFTER every reduction add succeeded, so an
    # in-place reduction would already have overwritten the root's buffer
    # with the partial sum by the time this fires.
    pool.table.register(DIV_KERNEL, lambda a, s: (_ for _ in ()).throw(
        ValueError("injected reduce failure")))
    with pytest.raises(ValueError, match="injected reduce"):
        PeerTransport().allreduce_mean(pool, handles, specs, root=root)
    pool.sync()
    for d in range(D):
        for j in range(2):
            np.testing.assert_array_equal(
                np.asarray(pool.transfer_from(d, handles[d][j])),
                np.asarray(values[d][j])), (d, j)
        # the gather scratch was freed on the failure path too
        assert len(pool.devices[d].store.live_handles()) == 2, d
    pool.stop_all()


# ---------------------------------------------------------------------------
# cost model: peer lanes
# ---------------------------------------------------------------------------
def test_peer_lanes_timed_not_adjusted():
    link = LinkModel("unit", bandwidth_Bps=1e6, latency_s=0.0)
    cm = CostModel(link)
    MB = int(1e6)
    cm.record_compute(0, 1.0)                 # dev0 [0, 1]
    cm.record_peer(0, 1, MB)                  # p0>1 [1, 2] (after dev0 compute)
    cm.record_peer(1, 2, MB)                  # p1>2 [0, 1] (dev1 full duplex:
                                              #   sending ∥ receiving)
    cm.record_peer(0, 1, MB)                  # p0>1 [2, 3] (link + tx0 + rx1
                                              #   all busy till 2)
    cm.record_compute(1, 0.5)                 # dev1 [3, 3.5]: waits for its
                                              #   in-flight peer payloads
    assert cm.bytes_peer() == 3 * MB
    assert cm.bytes_moved() == 0              # nothing on the host NIC
    assert cm.comm_time() == 0.0
    # per-link serialization, links concurrent: p0>1 carries 2 MB
    assert cm.peer_time() == pytest.approx(2.0)
    spans = {(s.lane, s.start, s.end) for s in cm.timeline()}
    assert ("p0>1", 1.0, 2.0) in spans
    assert ("p1>2", 0.0, 1.0) in spans
    assert ("p0>1", 2.0, 3.0) in spans
    assert ("dev1", 3.0, 3.5) in spans
    assert cm.makespan(overlap=True) == pytest.approx(3.5)
    # paper-model serialization: max per-device compute + peer link time
    assert cm.makespan() == pytest.approx(1.0 + 2.0)


def test_ring_round_is_concurrent_across_links():
    """One ring round over D devices costs one link's time in the overlap
    timeline, not D: links are distinct lanes and endpoints are full
    duplex — the 'concurrent links' the peer_time() model promises, so
    makespan(overlap=True) never exceeds the serialized makespan()."""
    link = LinkModel("unit", bandwidth_Bps=1e6, latency_s=0.0)
    cm = CostModel(link)
    D, MB = 4, int(1e6)
    for d in range(D):                        # the round: 0>1, 1>2, 2>3, 3>0
        cm.record_peer(d, (d + 1) % D, MB)
    spans = cm.timeline()
    assert all(s.start == 0.0 and s.end == 1.0 for s in spans), spans
    assert cm.makespan(overlap=True) == pytest.approx(1.0)
    assert cm.peer_time() == pytest.approx(1.0)
    assert cm.makespan(overlap=True) <= cm.makespan()


def test_peer_link_model_override():
    fast = LinkModel("ici", bandwidth_Bps=1e9, latency_s=0.0)
    cm = CostModel(LinkModel("slow", bandwidth_Bps=1e6, latency_s=0.0),
                   peer_link=fast)
    cm.record_peer(0, 1, int(1e6))
    assert cm.peer_time() == pytest.approx(1e6 / 1e9)


# ---------------------------------------------------------------------------
# property: interleaved SEND/RECV x EXEC/XFER == serial dispatch
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["xfer", "exec", "peer01", "peer10"]),
                          st.integers(0, 99)),
                min_size=1, max_size=12),
       st.integers(0, 3))
def test_random_interleavings_match_serial(ops, stall):
    """Random programs over two devices sharing one logical buffer pair:
    host writes, on-device EXECs, and peer copies in both directions.  The
    async dependency-aware dispatch (with a stalled worker forcing maximal
    issue-ahead) must leave both buffers bit-identical to a serial replay."""
    # serial reference on the host
    ref = {0: np.zeros(4, np.float32), 1: np.zeros(4, np.float32)}
    for kind, val in ops:
        if kind == "xfer":
            ref[val % 2] = np.full(4, float(val), np.float32)
        elif kind == "exec":
            ref[val % 2] = ref[val % 2] * 3.0 + 1.0
        elif kind == "peer01":
            ref[1] = ref[0].copy()
        else:
            ref[0] = ref[1].copy()

    pool = _pool(2)
    h = {d: _install(pool, d, jnp.zeros(4, jnp.float32)) for d in (0, 1)}
    gate = threading.Event()
    if stall < 2:                    # sometimes stall one worker during issue
        pool._submit(stall, gate.wait)
        # a synchronous EXEC on the stalled device must still make progress:
        # release the gate shortly, keeping issue-ahead pressure until then
        threading.Timer(0.1, gate.set).start()
    for kind, val in ops:
        if kind == "xfer":
            pool.transfer_to(val % 2, h[val % 2],
                             jnp.full(4, float(val), jnp.float32))
        elif kind == "exec":
            d = val % 2
            out = pool.exec_kernel(d, "triple", buffers={"a": h[d]})
            pool.transfer_to_writeback(d, h[d], out["a"])
        elif kind == "peer01":
            pool.peer_copy(0, h[0], 1, h[1])
        else:
            pool.peer_copy(1, h[1], 0, h[0])
    gate.set()
    pool.sync()
    for d in (0, 1):
        got = np.asarray(pool.transfer_from(d, h[d]))
        np.testing.assert_array_equal(got, ref[d]), (d, ops)
    pool.stop_all()
