"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import gqa_flash_attention
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd_chunked_pallas
from repro.kernels.ssd_scan.ref import ssd_naive_ref, ssd_scan_ref
from repro.kernels.grouped_matmul.ops import expert_ffn_matmul
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref
from repro.kernels.mandelbrot.mandelbrot import mandelbrot
from repro.kernels.mandelbrot.ref import mandelbrot_ref
from repro.kernels.block_lu.block_lu import bmod
from repro.kernels.block_lu.ref import bmod_ref, lu0_ref, fwd_ref, bdiv_ref


def _tol(dtype):
    # bf16 ulp at magnitude ~2-4 is 0.016-0.03: a single last-place rounding
    # difference from accumulation order must not fail the sweep
    return dict(rtol=4e-2, atol=4e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("Sq,Skv,d", [(128, 128, 32), (256, 128, 64),
                                      (64, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(Sq, Skv, d, dtype):
    BK, r = 2, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (BK, r, Sq, d), dtype)
    k = jax.random.normal(ks[1], (BK, Skv, d), dtype)
    v = jax.random.normal(ks[2], (BK, Skv, d), dtype)
    causal = Sq == Skv                      # causal only for square
    o = flash_attention(q, k, v, causal=causal, interpret=True,
                        block_q=64, block_kv=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [0, 32])
def test_flash_attention_window(window):
    BK, r, S, d = 2, 1, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (BK, r, S, d))
    k = jax.random.normal(ks[1], (BK, S, d))
    v = jax.random.normal(ks[2], (BK, S, d))
    o = flash_attention(q, k, v, causal=True, window=window, interpret=True,
                        block_q=32, block_kv=32)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gqa_layout_wrapper():
    B, S, H, K, d = 2, 64, 8, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, K, d))
    v = jax.random.normal(ks[2], (B, S, K, d))
    o = gqa_flash_attention(q, k, v, causal=True, interpret=True,
                            block_q=32, block_kv=32)
    # oracle via model-layer dense attention
    from repro.models.attention import dense_attention
    ref = dense_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,chunk", [(64, 16), (96, 32), (128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_shapes(S, chunk, dtype):
    b, H, P, G, N = 2, 4, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, G, N), dtype)
    C = jax.random.normal(ks[4], (b, S, G, N), dtype)
    y, h = ssd_chunked_pallas(x, dt, A, B, C, chunk=chunk, interpret=True)
    from repro.models.ssm import ssd_chunked
    yr, hr = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32),
                               **_tol(dtype))


def test_ssd_scan_matches_sequential_recurrence():
    """Chunked kernel == literal per-step recurrence (independent oracle)."""
    b, S, H, P, G, N = 1, 32, 2, 8, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, G, N))
    C = jax.random.normal(ks[4], (b, S, G, N))
    y, hf = ssd_chunked_pallas(x, dt, A, B, C, chunk=8, interpret=True)

    from repro.models.ssm import ssd_decode_step
    h = jnp.zeros((b, H, N, P))
    ys = []
    for t in range(S):
        yt, h = ssd_decode_step(h, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(yt)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("E,C,D,F", [(4, 32, 64, 32), (8, 16, 128, 64),
                                     (2, 128, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul(E, C, D, F, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    o = expert_ffn_matmul(x, w, interpret=True, block_c=16, block_f=32,
                          block_d=64)
    ref = grouped_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-1 if dtype == jnp.bfloat16 else 1e-3)


# ---------------------------------------------------------------------------
# mandelbrot
# ---------------------------------------------------------------------------
def test_mandelbrot_matches_ref():
    img = np.asarray(mandelbrot(64, 64, max_iter=50, interpret=True))
    ref = np.asarray(mandelbrot_ref(64, 64, max_iter=50))
    # escape-time is chaotically sensitive at the set boundary: tolerate
    # float-op-ordering flips on <0.5% of pixels (observed: 1/4096).
    mismatch = (img != ref).mean()
    assert mismatch < 0.005, f"{mismatch:.2%} pixels differ"


def test_mandelbrot_strips_tile_the_image():
    """Per-device strips (paper §5.4) reassemble to the full image."""
    full = mandelbrot(64, 32, max_iter=30, interpret=True)
    strips = [mandelbrot(16, 32, max_iter=30, row_offset=off, total_height=64,
                         interpret=True) for off in (0, 16, 32, 48)]
    np.testing.assert_array_equal(np.concatenate(strips, 0), np.asarray(full))


# ---------------------------------------------------------------------------
# block LU (sparselu ops)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,N,K", [(64, 64, 64), (128, 64, 32)])
def test_bmod(M, N, K):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    a = jax.random.normal(ks[0], (M, N))
    l = jax.random.normal(ks[1], (M, K))
    u = jax.random.normal(ks[2], (K, N))
    o = bmod(a, l, u, interpret=True, block_m=32, block_n=32, block_k=32)
    np.testing.assert_allclose(np.asarray(o), np.asarray(bmod_ref(a, l, u)),
                               rtol=1e-4, atol=1e-4)


def test_block_lu_factorization_correct():
    """lu0/fwd/bdiv/bmod compose into a correct 2×2 block factorization."""
    n = 16
    rng = np.random.default_rng(0)
    A = rng.standard_normal((2 * n, 2 * n)) + np.eye(2 * n) * 8
    A = jnp.asarray(A, jnp.float32)
    a00, a01 = A[:n, :n], A[:n, n:]
    a10, a11 = A[n:, :n], A[n:, n:]
    lu00 = lu0_ref(a00)
    u01 = fwd_ref(lu00, a01)
    l10 = bdiv_ref(lu00, a10)
    s11 = bmod_ref(a11, l10, u01)
    lu11 = lu0_ref(s11)
    # reconstruct
    L00 = np.tril(np.asarray(lu00), -1) + np.eye(n)
    U00 = np.triu(np.asarray(lu00))
    L11 = np.tril(np.asarray(lu11), -1) + np.eye(n)
    U11 = np.triu(np.asarray(lu11))
    L = np.block([[L00, np.zeros((n, n))], [np.asarray(l10), L11]])
    U = np.block([[U00, np.asarray(u01)], [np.zeros((n, n)), U11]])
    np.testing.assert_allclose(L @ U, np.asarray(A), rtol=1e-3, atol=1e-3)


def test_ssd_kernel_layout_refs_agree():
    """ssd_scan_ref (chunked oracle) == ssd_naive_ref (literal recurrence)."""
    BH, S, P, N = 3, 24, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (BH, S, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    A = -jnp.exp(jax.random.normal(ks[2], (BH,)) * 0.3)
    B = jax.random.normal(ks[3], (BH, S, N))
    C = jax.random.normal(ks[4], (BH, S, N))
    y1, h1 = ssd_scan_ref(x, dt, A, B, C, chunk=8)
    y2, h2 = ssd_naive_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash decode (serving hot spot; the kv-model policy's per-shard kernel)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,block", [(256, 64), (384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_vs_ref(S, block, dtype):
    from repro.kernels.flash_decode.flash_decode import flash_decode
    from repro.kernels.flash_decode.ref import flash_decode_ref
    BK, r, d = 3, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (BK, r, d), dtype)
    kc = jax.random.normal(ks[1], (BK, S, d), dtype)
    vc = jax.random.normal(ks[2], (BK, S, d), dtype)
    lens = jnp.asarray([S, S // 2, 7], jnp.int32)     # ragged valid lengths
    o = flash_decode(q, kc, vc, lens, block_kv=block, interpret=True)
    ref = flash_decode_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_decode_matches_model_decode_attention():
    """Kernel == the model's decode_attention (window=0) in model layout."""
    from repro.kernels.flash_decode.ops import gqa_flash_decode
    from repro.models.attention import decode_attention
    B, S, H, K, d = 2, 128, 8, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, H, d))
    kc = jax.random.normal(ks[1], (B, S, K, d))
    vc = jax.random.normal(ks[2], (B, S, K, d))
    kv_len = jnp.asarray(77, jnp.int32)
    o1 = gqa_flash_decode(q, kc, vc, kv_len, block_kv=32, interpret=True)
    o2 = decode_attention(q, kc, vc, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)
