"""Measured cost calibration: profiles, seeding, staleness, bit-identity.

The tentpole invariant, tested from three sides:

* a saved profile round-trips losslessly and seeds ``kernel_time`` /
  ``edge_time`` exactly as the in-memory one does;
* a profile that describes a different pool shape, topology, kernel table
  or schema version is rejected (:class:`StaleProfileError`), never
  silently applied;
* calibration reshapes *models only* — sparselu results are bitwise
  identical with calibration on or off, under every placement policy.
"""
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

from repro.core import (CalibrationProfile, ClusterRuntime, HeftPlacement,
                        KernelProfile, LinkProfile, RuntimeConfig,
                        StaleProfileError, Topology, fit_alpha_beta)
from repro.core.calibrate import SCHEMA_VERSION, host_info
from repro.core.costmodel import (CostModel, DEFAULT_KERNEL_TIME_S, LinkModel,
                                  PAPER_ETHERNET)
from repro.core.kernel_table import KernelTable
from repro.ft.stragglers import StragglerDetector


def _toy_table() -> KernelTable:
    t = KernelTable()
    t.register("axpy", lambda x, y: {"out": 2.0 * x + y},
               example=lambda: (jnp.ones((64, 64), jnp.float32),
                                jnp.ones((64, 64), jnp.float32)))
    t.register("scale", lambda x: {"out": 3.0 * x},
               example=lambda: jnp.ones((64, 64), jnp.float32))
    return t


def _synthetic_profile(n_devices, fingerprint, *, kernel_s=42e-6,
                       funnel=(2e9, 5e-6), peer=(1e7, 2e-4),
                       version=SCHEMA_VERSION, topology=None):
    return CalibrationProfile(
        version=version, created_unix=1.0, host=host_info(),
        n_devices=n_devices, table_fingerprint=fingerprint,
        topology=topology,
        kernels={"axpy": KernelProfile(name="axpy", seconds=kernel_s),
                 "scale": KernelProfile(name="scale", seconds=2 * kernel_s)},
        links={"funnel": LinkProfile("funnel", *funnel),
               "peer": LinkProfile("peer", *peer)})


# ---------------------------------------------------------------------------
# alpha-beta fit
# ---------------------------------------------------------------------------
def test_fit_alpha_beta_recovers_link():
    bw, lat = 5e8, 2e-4
    samples = [(n, lat + n / bw) for n in (1 << 14, 1 << 18, 1 << 22)] * 2
    got_lat, got_bw = fit_alpha_beta(samples)
    assert got_lat == pytest.approx(lat, rel=1e-6)
    assert got_bw == pytest.approx(bw, rel=1e-6)


def test_fit_alpha_beta_degenerate_clamps():
    lat, bw = fit_alpha_beta([(1024, 1e-4), (1024, 1.2e-4)])
    assert lat >= 0.0 and bw == 1e12
    # noisy tiny messages where time *decreases* with size: bandwidth clamps
    lat, bw = fit_alpha_beta([(1024, 2e-4), (4096, 1e-4)])
    assert bw == 1e12 and lat >= 0.0


# ---------------------------------------------------------------------------
# round trip + seeding
# ---------------------------------------------------------------------------
def test_profile_round_trip_seeds_identically(tmp_path):
    rt = ClusterRuntime(RuntimeConfig(n_virtual=2, link=PAPER_ETHERNET),
                        table=_toy_table())
    try:
        prof = rt.calibrate(reps=2, warmup=1, sizes=(1 << 12, 1 << 16),
                            save_dir=str(tmp_path))
        path = os.path.join(str(tmp_path), f"{prof.host['hostname']}.json")
        assert os.path.exists(path)
        loaded = CalibrationProfile.load(path)
        assert loaded.to_dict() == prof.to_dict()

        # a fresh runtime seeded from disk prices exactly like the live one
        rt2 = ClusterRuntime(RuntimeConfig(n_virtual=2, link=PAPER_ETHERNET),
                             table=_toy_table())
        try:
            rt2.load_calibration(path)
            for k in ("axpy", "scale"):
                assert rt2.cost.kernel_time(k) == prof.kernel_seed(k)
            assert rt2.cost.link == prof.link_model("funnel")
            nb = 1 << 16
            assert rt2.cost.link.time(nb) == \
                prof.link_model("funnel").time(nb)
        finally:
            rt2.shutdown()
    finally:
        rt.shutdown()


def test_calibration_discards_its_own_traffic():
    rt = ClusterRuntime(RuntimeConfig(n_virtual=2, link=PAPER_ETHERNET),
                        table=_toy_table())
    try:
        rt.calibrate(reps=2, warmup=1, sizes=(1 << 12, 1 << 16),
                     save_dir=None)
        assert rt.cost.transfers == []
        assert rt.cost.peers == []
        assert rt.cost.compute == []
    finally:
        rt.shutdown()


# ---------------------------------------------------------------------------
# staleness
# ---------------------------------------------------------------------------
def test_stale_profile_rejected():
    rt = ClusterRuntime(RuntimeConfig(n_virtual=2, link=PAPER_ETHERNET),
                        table=_toy_table())
    try:
        fp = rt.pool.table.fingerprint()
        # matching profile loads fine
        rt.load_calibration(_synthetic_profile(2, fp))
        # wrong device count
        with pytest.raises(StaleProfileError, match="devices"):
            rt.load_calibration(_synthetic_profile(4, fp))
        # wrong kernel table
        with pytest.raises(StaleProfileError, match="fingerprint"):
            rt.load_calibration(_synthetic_profile(2, "0" * 16))
        # wrong schema version
        with pytest.raises(StaleProfileError, match="schema"):
            rt.load_calibration(_synthetic_profile(2, fp, version=-1))
        # profiled under a topology this flat runtime does not have
        topo = Topology.two_tier(1, 2).describe()
        with pytest.raises(StaleProfileError, match="topology"):
            rt.load_calibration(_synthetic_profile(2, fp, topology=topo))
    finally:
        rt.shutdown()


def test_stale_topology_racks_mismatch():
    topo = Topology.two_tier(2, 2)
    rt = ClusterRuntime(RuntimeConfig(n_virtual=4, link=PAPER_ETHERNET,
                                      comm_mode="direct", topology=topo),
                        table=_toy_table())
    try:
        fp = rt.pool.table.fingerprint()
        ok = _synthetic_profile(4, fp, topology=topo.describe())
        rt.load_calibration(ok)
        other = Topology.two_tier(4, 1).describe()
        with pytest.raises(StaleProfileError, match="racks"):
            rt.load_calibration(_synthetic_profile(4, fp, topology=other))
    finally:
        rt.shutdown()


# ---------------------------------------------------------------------------
# kernel_time fallback ladder
# ---------------------------------------------------------------------------
def test_kernel_time_never_none_and_counts_cold():
    cost = CostModel()
    assert cost.kernel_time("nope") == DEFAULT_KERNEL_TIME_S
    assert cost.kernel_time("nope", default=7e-4) == 7e-4
    assert cost.summary()["cold_predictions"] == 2.0

    cost.profile = _synthetic_profile(1, None)
    assert cost.kernel_time("axpy") == 42e-6        # profile seed, not cold
    assert cost.summary()["cold_predictions"] == 2.0

    cost.record_compute(0, 1e-2, kernel="axpy")
    cost.record_compute(0, 2e-2, kernel="axpy")
    assert cost.kernel_time("axpy") == pytest.approx(1.5e-2)  # live wins
    assert cost.summary()["cold_predictions"] == 2.0


def test_reset_keeps_profile_clears_cold_counter():
    cost = CostModel()
    cost.profile = _synthetic_profile(1, None)
    cost.kernel_time("unseeded")
    assert cost.cold_predictions == 1
    cost.reset()
    assert cost.cold_predictions == 0
    assert cost.kernel_time("axpy") == 42e-6


def test_straggler_threshold_ignores_cold_default():
    cost = CostModel()
    det = StragglerDetector(cost, min_observations=2, grace_s=0.0)
    # no observations, no baseline: never hedge (despite kernel_time's
    # never-None ladder)
    assert det.threshold("axpy") is None
    det2 = StragglerDetector(cost, min_observations=2, grace_s=0.0,
                             baseline={"axpy": 1e-2})
    assert det2.threshold("axpy") == pytest.approx(3.0 * 1e-2)


# ---------------------------------------------------------------------------
# bit identity + determinism across policies
# ---------------------------------------------------------------------------
def _sparselu_run(policy, profile):
    from bots_sparselu import _build_dag, _make_table, _matrix
    K, B = 3, 16
    mat = _matrix(K, B)
    rt = ClusterRuntime(RuntimeConfig(n_virtual=3, link=PAPER_ETHERNET),
                        table=_make_table(K))
    try:
        if profile:
            prof = _synthetic_profile(3, rt.pool.table.fingerprint())
            prof.kernels = {k: KernelProfile(name=k, seconds=30e-6)
                            for k in ("lu0", "fwd", "bdiv", "bmod")}
            rt.load_calibration(prof)
        res = rt.wavefront_offload(_build_dag(mat, K, B), nowait=True,
                                   peer=True, policy=policy)
        values = {k: np.asarray(v) for k, v in res.items()}
        placements = [(p.task, p.device) for p in rt.cost.placements]
    finally:
        rt.shutdown()
    return values, placements


@pytest.mark.parametrize("policy", [
    "round-robin", "locality",
    HeftPlacement(default_task_s=5e-6, use_observed=False)],
    ids=["round-robin", "locality", "heft-frozen"])
def test_results_bit_identical_calibration_on_off(policy):
    base, _ = _sparselu_run(policy, profile=False)
    cal_policy = HeftPlacement(estimates="calibrated") \
        if isinstance(policy, HeftPlacement) else policy
    cal, _ = _sparselu_run(cal_policy, profile=True)
    assert sorted(base) == sorted(cal)
    for k in base:
        assert base[k].tobytes() == cal[k].tobytes(), k


def test_calibrated_estimates_are_deterministic():
    runs = [_sparselu_run(HeftPlacement(estimates="calibrated"),
                          profile=True) for _ in range(2)]
    assert runs[0][1] == runs[1][1]          # identical placement decisions
    for k in runs[0][0]:
        assert runs[0][0][k].tobytes() == runs[1][0][k].tobytes()


def test_heft_estimates_modes_validated():
    with pytest.raises(ValueError, match="estimates"):
        HeftPlacement(estimates="vibes")
    assert HeftPlacement(use_observed=False).estimates == "frozen"
    assert HeftPlacement().estimates == "observed"


# ---------------------------------------------------------------------------
# roofline report plumbing
# ---------------------------------------------------------------------------
def test_placement_report_roofline_payload():
    cost = CostModel()
    cost.profile = _synthetic_profile(1, None)
    cost.profile.kernels["axpy"].flops = 8192.0
    cost.profile.kernels["axpy"].bytes_accessed = 49152.0
    cost.record_compute(0, 50e-6, kernel="axpy")
    rep = cost.placement_report(roofline=True)
    assert set(rep) == {"placements", "roofline"}
    rows = {r["kernel"]: r for r in rep["roofline"]}
    axpy = rows["axpy"]
    assert axpy["observed_s"] == pytest.approx(50e-6)
    assert axpy["calibrated_s"] == pytest.approx(42e-6)
    assert axpy["model_ratio"] == pytest.approx(50e-6 / 42e-6)
    assert axpy["intensity"] == pytest.approx(8192.0 / 49152.0)
    assert axpy["bound"] == "memory"
    # seeded-but-never-run kernel still shows up, with no observed side
    assert rows["scale"]["observed_s"] is None
    assert rows["scale"]["calibrated_s"] == pytest.approx(84e-6)
